"""Distributed clustering — the paper's kernels at pod scale.

Two distribution strategies, recorded for the §Perf comparison:

1. **pjit / GSPMD** (`make_sharded_kmeans_step`, `sharded_degree`): points are
   sharded over the (pod, data) axes, centroids/frontier replicated; the
   one-hot-matmul centroid update and the degree reduction become partial
   sums + a single all-reduce inserted by GSPMD.  Zero custom communication —
   the pod-scale version of the paper's "same kernel, different device"
   portability.

2. **Ring systolic** (`ring_degree`, `ring_expand`): for DBSCAN the full
   (n, n) adjacency never fits anywhere; the pjit path would all-gather X
   per device (n*d bytes) before tiling.  The ring variant keeps only
   1/p-th of X per device and rotates column-shards with
   `lax.ppermute` p times, so peak per-device live bytes drop from
   n*d to 2*(n/p)*d while the permute of step s+1 can overlap the tile
   compute of step s (XLA latency-hiding scheduler; verified in the dry-run
   HLO).  This is the beyond-paper distributed optimization for the
   technique's own dry-run cell.

Both strategies now also back the serving layer's ``distributed`` paradigm
(:mod:`repro.service.dispatch`): one request too large for a single device
is sharded over every local device and driven by the *resumable* host loops
at the bottom of this module — :func:`sharded_kmeans_fit_resumable` and
:func:`sharded_dbscan_fit_resumable` — which poll the paper's abort flag
between collective launches and snapshot device-agnostic state (replicated
centroids, gathered packed word + frontier), so a sharded job killed
mid-shard resumes exactly like a single-device job, even on a host with a
different device count.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro.runtime.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cancellation import CancellationToken
from repro.core.dbscan import (
    DBSCANConfig,
    DBSCANResult,
    DBSCANRunState,
    MAX_CLUSTER_ID,
    finish,
    pack_state,
    unpack_state,
)
from repro.core.kmeans import (
    KMeansConfig,
    KMeansResult,
    kmeans_step,
    masked_kmeans_step,
)
from repro.kernels.distance.ref import assign_clusters_ref
from repro.kernels.neighbor.ref import _sq_dists  # noqa: F401 (docs)


def local_mesh(axis: str = "data") -> Mesh:
    """1-D mesh over every local device (the serving layer's shard domain).

    Device discovery goes through the wrapper library
    (:func:`repro.runtime.backend.discover_backend`), never at import time.
    """
    from repro.runtime import backend as backend_mod

    backend = backend_mod.discover_backend()
    return Mesh(np.asarray(backend.devices), (axis,))


def shard_rows(n: int, shards: int) -> int:
    """Rows per shard so ``shards * shard_rows(n, shards) >= n``."""
    return -(-n // max(1, shards))


# ---------------------------------------------------------------------------
# Strategy 1: pjit / GSPMD
# ---------------------------------------------------------------------------

def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of a production mesh ((pod,)data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_sharded_kmeans_step(mesh: Mesh, cfg: KMeansConfig):
    """Jitted K-Means step with points sharded over (pod, data).

    GSPMD inserts: an all-reduce of the (k, d) partial centroid sums and the
    (k,) partial counts over the data axes.  Everything else is local.
    """
    daxes = data_axes(mesh)
    x_sharding = NamedSharding(mesh, P(daxes, None))
    c_sharding = NamedSharding(mesh, P())
    a_sharding = NamedSharding(mesh, P(daxes))

    def step(x, c):
        return kmeans_step(x, c, cfg)

    return jax.jit(
        step,
        in_shardings=(x_sharding, c_sharding),
        out_shardings=(a_sharding, c_sharding, c_sharding, c_sharding),
    )


@functools.lru_cache(maxsize=32)
def make_sharded_masked_kmeans_step(mesh: Mesh, cfg: KMeansConfig):
    """Like :func:`make_sharded_kmeans_step` but over a *padded* batch item:
    points and the validity mask are sharded, masked-out rows carry no
    weight, so the serving layer's pow2-bucketed requests shard without
    perturbing their results.  Cached per (mesh, cfg): the serving host loop
    calls this every step and must reuse one executable.
    """
    daxes = data_axes(mesh)
    x_sharding = NamedSharding(mesh, P(daxes, None))
    m_sharding = NamedSharding(mesh, P(daxes))
    c_sharding = NamedSharding(mesh, P())

    def step(x, c, mask):
        return masked_kmeans_step(x, c, mask, cfg)

    return jax.jit(
        step,
        in_shardings=(x_sharding, c_sharding, m_sharding),
        out_shardings=(m_sharding, c_sharding, c_sharding, c_sharding),
    )


# ---------------------------------------------------------------------------
# Strategy 2: ring systolic (shard_map + ppermute)
# ---------------------------------------------------------------------------

def _pvary(x, axis: str):
    """Mark a constant as device-varying over `axis` (shard_map VMA typing)."""
    from repro.runtime.compat import pvary

    return pvary(x, axis)


def _ring_body(x_rows, x_cols0, combine, init, axis: str):
    """Rotate column shards around the ring, folding tiles into `init`."""
    from repro.runtime.compat import axis_size

    p = axis_size(axis)
    me = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % p) for i in range(p)]
    init = jax.tree.map(lambda a: _pvary(a, axis), init)

    def body(step, carry):
        acc, x_cols = carry
        # which global column shard we currently hold
        shard_idx = (me - step) % p
        acc = combine(acc, x_rows, x_cols, shard_idx)
        x_cols = jax.lax.ppermute(x_cols, axis, perm)
        return acc, x_cols

    acc, _ = jax.lax.fori_loop(0, p, body, (init, x_cols0))
    return acc


def _tile_adj(xi, xj, eps2):
    xi = xi.astype(jnp.float32)
    xj = xj.astype(jnp.float32)
    cross = xi @ xj.T
    d2 = (
        jnp.sum(xi * xi, 1)[:, None]
        - 2.0 * cross
        + jnp.sum(xj * xj, 1)[None, :]
    )
    return d2 <= eps2


@functools.lru_cache(maxsize=32)
def make_ring_degree(mesh: Mesh, eps: float, axis: str = "data"):
    """Cached jitted ring-degree (jit reuses one executable per shape —
    the serving host loops call this once per kernel launch)."""
    eps2 = float(eps) ** 2

    def local(x_shard):
        def combine(acc, rows, cols, _):
            return acc + jnp.sum(
                _tile_adj(rows, cols, eps2).astype(jnp.int32), axis=1
            )

        init = jnp.zeros((x_shard.shape[0],), jnp.int32)
        return _ring_body(x_shard, x_shard, combine, init, axis)

    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis)
    ))


@functools.lru_cache(maxsize=32)
def make_ring_expand(mesh: Mesh, eps: float, axis: str = "data"):
    """Cached jitted ring frontier expansion (one BFS depth per call)."""
    eps2 = float(eps) ** 2

    def local(x_shard, f_shard):
        def combine(acc, rows, cols_and_f, _):
            cols, f = cols_and_f
            hit = _tile_adj(rows, cols, eps2) & f[None, :]
            return acc | jnp.any(hit, axis=1)

        init = jnp.zeros((x_shard.shape[0],), bool)
        return _ring_body(x_shard, (x_shard, f_shard), combine, init, axis)

    return jax.jit(shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis)),
        out_specs=P(axis),
    ))


def ring_degree(mesh: Mesh, x: jax.Array, eps: float, axis: str = "data"):
    """deg[i] over row-sharded x without materializing replicated X."""
    return make_ring_degree(mesh, float(eps), axis)(x)


def ring_expand(
    mesh: Mesh, x: jax.Array, frontier: jax.Array, eps: float,
    axis: str = "data",
):
    """reach[i] = any_j adj[i,j] & frontier[j], ring-rotated like above."""
    return make_ring_expand(mesh, float(eps), axis)(x, frontier)


# ---------------------------------------------------------------------------
# Resumable sharded fits — the serving layer's oversized-request path
# ---------------------------------------------------------------------------
#
# Both loops mirror their single-device twins (`kmeans.fit_cancellable`,
# `dbscan.fit_resumable`): the abort flag is polled between collective
# launches, and the state reported through ``on_state`` is *gathered to the
# host* and device-count independent — K-Means state is the replicated
# (k, d) centroid matrix + iteration counter, DBSCAN state is the paper's
# packed int16 word + BFS frontier over all rows.  A checkpoint written on a
# 4-device mesh therefore resumes on 1 device (or 8) bit-identically.


def sharded_kmeans_fit_resumable(
    mesh: Mesh,
    x_pad: np.ndarray,
    mask: np.ndarray,
    cfg: KMeansConfig,
    token: Optional[CancellationToken] = None,
    *,
    centroids: np.ndarray,
    start_iteration: int = 0,
    on_state: Optional[Callable[[Dict[str, np.ndarray]], None]] = None,
    state_interval: int = 8,
) -> Tuple[KMeansResult, Optional[Dict[str, np.ndarray]]]:
    """Masked Lloyd host loop with points/mask sharded over the mesh.

    ``x_pad`` must have rows divisible by the mesh's data extent (the
    caller pads; see ``shard_rows``).  Returns ``(result, mid_state)`` where
    ``mid_state`` is the resume snapshot on cancellation (None otherwise),
    in the same tree form the single-device paradigm checkpoints.
    """
    daxes = data_axes(mesh)
    step = make_sharded_masked_kmeans_step(mesh, cfg)
    xs = jax.device_put(jnp.asarray(x_pad, jnp.float32),
                        NamedSharding(mesh, P(daxes, None)))
    ms = jax.device_put(jnp.asarray(mask, bool),
                        NamedSharding(mesh, P(daxes)))
    c = jnp.asarray(centroids, jnp.float32)
    assign = jnp.zeros((x_pad.shape[0],), jnp.int32)
    inertia = jnp.float32(jnp.inf)
    it = start_iteration
    stepped = False
    converged = False
    cancelled = False
    while it < cfg.max_iters:
        if token is not None and token.cancelled():
            cancelled = True
            break
        assign, c, shift, inertia = step(xs, c, ms)
        stepped = True
        it += 1
        if on_state is not None and it % state_interval == 0:
            on_state({
                "centroids": np.asarray(c, np.float32),
                "iteration": np.int32(it),
            })
        if float(shift) < cfg.tol:
            converged = True
            break
    if not stepped and not cancelled:
        # resumed at (or past) the iteration ceiling: the checkpoint holds
        # centroids but no labels.  One step yields the assignment/inertia
        # of the *incoming* centroids (computed before the update), which
        # we keep — without it the result would be all-zero labels.
        assign, _, _, inertia = step(xs, c, ms)
    result = KMeansResult(
        centroids=c,
        labels=jnp.asarray(assign).astype(jnp.int16),
        inertia=inertia,
        iterations=jnp.int32(it),
        converged=jnp.asarray(converged),
        cancelled=cancelled,
    )
    mid = None
    if cancelled:
        mid = {
            "centroids": np.asarray(c, np.float32),
            "iteration": np.int32(it),
        }
    return result, mid


def sharded_dbscan_fit_resumable(
    mesh: Mesh,
    x_pad: np.ndarray,
    cfg: DBSCANConfig,
    token: Optional[CancellationToken] = None,
    *,
    state: Optional[DBSCANRunState] = None,
    valid_mask: Optional[np.ndarray] = None,
    on_state: Optional[Callable[[DBSCANRunState], None]] = None,
    state_interval: int = 8,
    axis: str = "data",
) -> Tuple[DBSCANResult, Optional[DBSCANRunState]]:
    """DBSCAN host loop with the two O(n^2) kernels ring-sharded.

    The degree kernel and every frontier expansion run as ring collectives
    (1/p-th of X per device); the O(n) bookkeeping — the paper's packed
    int16 word — stays on the host, which is exactly what makes the state
    checkpointable and mesh-shape independent.  Same contract as
    :func:`repro.core.dbscan.fit_resumable`.
    """
    n = x_pad.shape[0]
    degree_fn = make_ring_degree(mesh, float(cfg.eps), axis)
    expand_fn = make_ring_expand(mesh, float(cfg.eps), axis)
    x_sharding = NamedSharding(mesh, P(axis, None))
    f_sharding = NamedSharding(mesh, P(axis))
    xs = jax.device_put(jnp.asarray(x_pad, jnp.float32), x_sharding)

    deg = np.asarray(degree_fn(xs))          # ring launch 1 (degree kernel)
    core = deg >= cfg.min_pts
    if valid_mask is not None:
        core = core & np.asarray(valid_mask)

    if state is not None:
        labels, visited, member, _ = (
            np.asarray(a) for a in unpack_state(np.asarray(state.packed)))
        frontier = np.asarray(state.frontier, bool)
        cid = int(state.cid)
        nexp = int(state.nexp)
    else:
        labels = np.zeros((n,), np.int32)
        visited = np.zeros((n,), bool)
        member = np.zeros((n,), bool)
        frontier = np.zeros((n,), bool)
        cid = 0
        nexp = 0
    cancelled = False

    def _poll() -> bool:
        return token is not None and token.cancelled()

    def _snapshot() -> DBSCANRunState:
        return DBSCANRunState(
            packed=np.asarray(pack_state(labels, visited, member, core)),
            frontier=np.asarray(frontier),
            cid=cid,
            nexp=nexp,
        )

    while True:
        while bool(frontier.any()):
            if _poll():
                cancelled = True
                break
            fs = jax.device_put(jnp.asarray(frontier), f_sharding)
            reached = np.asarray(expand_fn(xs, fs))   # ring expansion launch
            nexp += 1
            new = reached & (labels == 0)
            labels = np.where(new, cid, labels)
            visited = visited | new
            member = member | new
            frontier = new & core
            if on_state is not None and nexp % state_interval == 0:
                on_state(_snapshot())
        if cancelled or _poll():
            cancelled = True
            break
        todo = core & ~visited
        if not todo.any():
            break
        cid += 1
        if cid > MAX_CLUSTER_ID:
            raise ValueError(
                f"dataset produced more than {MAX_CLUSTER_ID} clusters — the "
                f"paper's int16 state word cannot represent cluster id {cid}"
            )
        frontier = np.zeros((n,), bool)
        frontier[int(np.argmax(todo))] = True

    packed = pack_state(labels, visited, member, core)
    result = DBSCANResult(
        labels=finish(packed),
        core_mask=jnp.asarray(core),
        n_clusters=jnp.int32(cid),
        expansions=jnp.int32(nexp),
        cancelled=cancelled,
    )
    return result, (_snapshot() if cancelled else None)


# ---------------------------------------------------------------------------
# Dry-run entry: one distributed K-Means step as a lowerable function
# ---------------------------------------------------------------------------

def clustering_step_for_dryrun(cfg: KMeansConfig):
    """A (x, c) -> (assign, c', shift, inertia) function for lower+compile.

    Same math as the Pallas assignment kernel (MXU decomposition
    ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2): the cross term is one big
    (n, d) x (d, k) matmul with points sharded over (pod, data) and
    centroids sharded over 'model', so the (n, k) score matrix is 2-D
    sharded and the naive (n, k, d) broadcast never exists.  The centroid
    update is the one-hot matmul; its (k, d) partial sums all-reduce over
    the data axes is the step's only meaningful collective.
    """
    from repro.parallel.sharding import lshard  # noqa: PLC0415

    def step(x, c):
        xf = x.astype(jnp.float32)
        cf = c.astype(jnp.float32)
        cross = jnp.einsum("nd,kd->nk", xf, cf,
                           preferred_element_type=jnp.float32)
        cross = lshard(cross, "points", "centroids")
        cnorm = jnp.sum(cf * cf, axis=1)
        score = cnorm[None, :] - 2.0 * cross          # argmin-equivalent
        assign = jnp.argmin(score, axis=1)
        xnorm = jnp.sum(xf * xf, axis=1)
        d2min = jnp.maximum(jnp.min(score, axis=1) + xnorm, 0.0)

        onehot = jax.nn.one_hot(assign, cfg.k, dtype=jnp.float32)
        onehot = lshard(onehot, "points", "centroids")
        sums = jnp.einsum("nk,nd->kd", onehot, xf)
        counts = jnp.sum(onehot, axis=0)
        has = counts > 0
        c_new = jnp.where(has[:, None],
                          sums / jnp.where(has, counts, 1.0)[:, None], cf)
        return assign, c_new, jnp.sum(jnp.abs(c_new - cf)), jnp.sum(d2min)

    return step
