"""Cooperative cancellation — the paper's abort-flag protocol, distributed.

Paper §II.A: long-running jobs must stop "timely" when the user presses a
button, but a kernel in flight cannot be interrupted — so every
implementation "has to test from time to time a flag and check if they should
abort immediately.  For the implementations that use the GPU, the flag is
tested between OpenCL kernel executions.  The flag is accessed using the
reader lock of the RW lock.  To terminate the calculations prematurely, a
special method acquires the writer lock."

TPU translation: a dispatched jitted step is uninterruptible the same way an
OpenCL kernel launch is, so the token is polled **between steps** (training
steps, clustering iterations, DBSCAN cluster expansions).  Readers are the
worker loops; the writer is whoever cancels (a signal handler, a watchdog, an
operator RPC).  Writer preference guarantees the flag flips as soon as the
in-flight step returns, no matter how many reader polls are queued.
"""

from __future__ import annotations

import enum
import threading
import time
from typing import Callable, List, Optional

from repro.runtime.locks import RWLock


class CancelReason(enum.Enum):
    NONE = "none"
    USER = "user"                # paper: the button in the app
    PREEMPTION = "preemption"    # paper: activity suspended / OS doze
    WATCHDOG = "watchdog"        # straggler mitigation
    ERROR = "error"


class CancellationToken:
    """Abort flag guarded by a writer-preferred reentrant RW lock."""

    def __init__(self) -> None:
        self._lock = RWLock()
        self._cancelled = False
        self._reason = CancelReason.NONE
        self._cancelled_at: Optional[float] = None
        self._callbacks: List[Callable[[CancelReason], None]] = []

    # -- reader side (polled between kernel executions / steps) -------------

    def cancelled(self) -> bool:
        with self._lock.read():
            return self._cancelled

    @property
    def reason(self) -> CancelReason:
        with self._lock.read():
            return self._reason

    def raise_if_cancelled(self) -> None:
        with self._lock.read():
            if self._cancelled:
                raise JobCancelled(self._reason)

    # -- writer side ----------------------------------------------------------

    def cancel(self, reason: CancelReason = CancelReason.USER) -> None:
        with self._lock.write():
            if self._cancelled:
                return
            self._cancelled = True
            self._reason = reason
            self._cancelled_at = time.monotonic()
            callbacks = list(self._callbacks)
        for cb in callbacks:  # outside the lock: callbacks may re-enter
            cb(reason)

    def reset(self) -> None:
        with self._lock.write():
            self._cancelled = False
            self._reason = CancelReason.NONE
            self._cancelled_at = None

    def on_cancel(self, cb: Callable[[CancelReason], None]) -> None:
        with self._lock.write():
            self._callbacks.append(cb)

    @property
    def latency(self) -> Optional[float]:
        """Seconds since cancel() was called (None if not cancelled)."""
        with self._lock.read():
            if self._cancelled_at is None:
                return None
            return time.monotonic() - self._cancelled_at


class JobCancelled(Exception):
    def __init__(self, reason: CancelReason) -> None:
        super().__init__(f"job cancelled: {reason.value}")
        self.reason = reason


def cancel_after(token: CancellationToken, seconds: float,
                 reason: CancelReason = CancelReason.USER) -> threading.Timer:
    """Arm a timer that cancels the token (used in tests and examples)."""
    t = threading.Timer(seconds, token.cancel, kwargs={"reason": reason})
    t.daemon = True
    t.start()
    return t
