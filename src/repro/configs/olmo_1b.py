"""olmo-1b [dense]: non-parametric LayerNorm, tied embeddings.

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 [arXiv:2402.00838; hf].
OLMo's LN has no learned affine (norm="nonparam_ln"); SwiGLU MLP with the
published d_ff=8192 total hidden.
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=8192,
        vocab=50304,
        norm="nonparam_ln",
        act="swiglu",
        tie_embeddings=True,
        pattern=DENSE_PATTERN,
        source="[arXiv:2402.00838; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab=512,
        norm="nonparam_ln",
        act="swiglu",
        tie_embeddings=True,
        pattern=DENSE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
