"""internvl2-26b [vlm]: InternLM2-20B-style backbone behind InternViT.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf].  The ViT frontend is a stub: input_specs provides
precomputed patch embeddings (256 patches -> d_model), per the assignment.
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab=92553,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=1_000_000.0,
        pattern=DENSE_PATTERN,
        frontend="vlm",
        prefix_len=256,
        source="[arXiv:2404.16821; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        pattern=DENSE_PATTERN,
        frontend="vlm",
        prefix_len=8,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
