"""olmoe-1b-7b [moe]: 64 experts, top-8, 1B active / 7B total.

16L d_model=2048 16H (kv=16) d_ff_expert=1024 vocab=50304, MoE 64e top-8
[arXiv:2409.02060; hf].  The (64e, top-8) point is why the MoE layer uses
sort-based dispatch (see models/moe.py): the dispatch-mask einsum is
O(T*E*C) and explodes exactly here.
"""

from repro.configs.base import MOE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=0,
        vocab=50304,
        norm="rmsnorm",
        act="swiglu",
        n_experts=64,
        top_k=8,
        d_ff_expert=1024,
        pattern=MOE_PATTERN,
        source="[arXiv:2409.02060; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=0,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        n_experts=8,
        top_k=2,
        d_ff_expert=32,
        pattern=MOE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
