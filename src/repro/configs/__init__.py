"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each assigned architecture has its own module with the exact published
config plus a ``smoke()`` reduced config of the same family for CPU tests.
"""

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES

_ARCH_MODULES = {
    "internvl2-26b": "repro.configs.internvl2_26b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "olmo-1b": "repro.configs.olmo_1b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3p8b",
    "glm4-9b": "repro.configs.glm4_9b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).config()


def get_smoke_config(name: str) -> ModelConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).smoke()


__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "ARCH_NAMES",
    "get_config",
    "get_smoke_config",
]
