"""falcon-mamba-7b [ssm]: attention-free Mamba-1.

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified].  d_inner = 2*d_model = 8192,
dt_rank = d_model/16 = 256, conv kernel 4 (mamba1 reference shapes).
Runs the long_500k cell: decode state is O(1) in sequence length.
"""

from repro.configs.base import MAMBA_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        vocab=65024,
        d_ff=0,
        norm="rmsnorm",
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        dt_rank=256,
        pattern=MAMBA_PATTERN,
        source="[arXiv:2410.05355; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        vocab=512,
        d_ff=0,
        norm="rmsnorm",
        ssm_state=4,
        ssm_conv=4,
        ssm_expand=2,
        dt_rank=8,
        pattern=MAMBA_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
