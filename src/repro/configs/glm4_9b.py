"""glm4-9b [dense]: extreme GQA (2 KV heads vs 32 Q heads).

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
[hf:THUDM/glm-4-9b; hf].  The kv=2 < TP=16 case is the interesting sharding
cell: Q heads shard 2-per-device while KV heads must be replicated 8-way
(GSPMD inserts the all-gather); see EXPERIMENTS.md.
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_head=128,
        d_ff=13696,
        vocab=151552,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=5_000_000.0,
        pattern=DENSE_PATTERN,
        source="[hf:THUDM/glm-4-9b; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        pattern=DENSE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
