"""minicpm-2b [dense]: llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (GQA kv=36, i.e. MHA) d_ff=5760 vocab=122753
[arXiv:2404.06395; hf].  The WSD (warmup-stable-decay) schedule the paper
introduces is implemented in repro.optim.schedule and selected by this
config's trainer defaults.
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_head=64,
        d_ff=5760,
        vocab=122753,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        pattern=DENSE_PATTERN,
        source="[arXiv:2404.06395; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=6,
        d_head=8,
        d_ff=96,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        pattern=DENSE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
