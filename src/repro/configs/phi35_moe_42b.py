"""phi3.5-moe-42b-a6.6b [moe]: 16 experts, top-2.

32L d_model=4096 32H (GQA kv=8) d_ff_expert=6400 vocab=32064, MoE 16e top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].
"""

from repro.configs.base import MOE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=0,
        vocab=32064,
        norm="layernorm",
        act="swiglu",
        n_experts=16,
        top_k=2,
        d_ff_expert=6400,
        pattern=MOE_PATTERN,
        source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=0,
        vocab=512,
        norm="layernorm",
        act="swiglu",
        n_experts=4,
        top_k=2,
        d_ff_expert=32,
        pattern=MOE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
