"""phi3-mini-3.8b [dense]: RoPE + SwiGLU + (degenerate) GQA.

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064 [arXiv:2404.14219;
unverified].
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab=32064,
        norm="rmsnorm",
        act="swiglu",
        pattern=DENSE_PATTERN,
        source="[arXiv:2404.14219; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_head=12,
        d_ff=96,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        pattern=DENSE_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
