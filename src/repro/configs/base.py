"""Model/shape configuration dataclasses shared by every architecture."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# (mixer, ff) per sub-layer of one scan period.
# mixer: "attn" | "mamba";  ff: "dense" | "moe" | None (mamba1 has no FFN)
Pattern = Tuple[Tuple[str, Optional[str]], ...]

DENSE_PATTERN: Pattern = (("attn", "dense"),)
MOE_PATTERN: Pattern = (("attn", "moe"),)
MAMBA_PATTERN: Pattern = (("mamba", None),)
# Jamba: 1 attention per 8 layers (1:7), MoE every other layer.
JAMBA_PATTERN: Pattern = (
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("attn", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # attention (0s for attn-free archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    rope_theta: float = 10_000.0
    # normalization: rmsnorm | layernorm | nonparam_ln (OLMo)
    norm: str = "rmsnorm"
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0
    # layer pattern (period); n_layers % len(pattern) == 0
    pattern: Pattern = DENSE_PATTERN
    # modality frontend stub
    frontend: str = "none"       # none | vlm | audio
    prefix_len: int = 0          # frames/patches prepended by the stub
    # numerics / compile strategy
    dtype: str = "bfloat16"
    remat: str = "full"          # none | dots | full
    scan_layers: bool = True
    # query-chunked (flash-style streaming) attention above this seq len;
    # bounds the live score buffer to (B, H, chunk, S).  0 = never chunk.
    attn_chunk: int = 2048
    # head-count padding granularity (16 = the production TP degree;
    # smoke configs use 4 to exercise the masked-padding path cheaply)
    head_pad_multiple: int = 16
    # chunked cross-entropy: split the batch into this many strided
    # sub-chunks and recompute logits per chunk in the backward pass, so
    # the (B, S, vocab) f32 logits tensor is never materialized (decisive
    # for vocab >= 92k).  0 = off; analysis compiles override to 0.
    loss_chunk: int = 16
    # MoE dispatch group size (tokens): the (group*k, d) gather/scatter
    # chain is the top-k dispatch's memory spine (8x token volume for
    # OLMoE); chunks are scanned with per-chunk remat.  0 = whole sequence.
    moe_chunk: int = 1024
    ssm_chunk: int = 128         # associative-scan chunk length
    # source note: [reference; verification tier]
    source: str = ""

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_heads_padded(self) -> int:
        """Megatron-style head padding to a TP-friendly multiple (16).

        Published head counts that don't divide 16-way TP (36, 24) are
        padded in the *layout*; padded heads are masked to exactly zero
        output in models.layers.attention, so semantics match the
        published config (see DESIGN.md §7)."""
        m = self.head_pad_multiple
        return -(-self.n_heads // m) * m if self.n_heads else 0

    @property
    def n_kv_heads_padded(self) -> int:
        """KV heads are padded only in the MHA case (kv == heads).  GQA
        archs (kv 2/8) keep their published KV count: replicating a few KV
        heads is cheaper than 2-8x padded KV cache; their decode caches
        shard over the sequence dim instead (launch/cells.rules_for)."""
        if self.n_kv_heads and self.n_kv_heads == self.n_heads:
            return self.n_heads_padded
        return self.n_kv_heads

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to a TP-friendly multiple (256).

        The embedding table and lm_head are laid out padded so "vocab" can
        shard over 16-way model parallelism even for odd published vocabs
        (92553, 122753); padded logit columns are masked to -inf in
        models.lm._logits, so semantics match the published config exactly.
        """
        return -(-self.vocab // 256) * 256

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.period}"
        )
        return self.n_layers // self.period

    @property
    def attention_free(self) -> bool:
        return all(mixer != "attn" for mixer, _ in self.pattern)

    @property
    def has_attention(self) -> bool:
        return not self.attention_free

    @property
    def full_attention(self) -> bool:
        """True if *every* mixer is full (quadratic) attention."""
        return all(mixer == "attn" for mixer, _ in self.pattern)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d = self.d_model
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        for mixer, ff in self.pattern * self.n_groups:
            if mixer == "attn":
                total += d * self.n_heads * self.d_head        # q
                total += 2 * d * self.n_kv_heads * self.d_head  # k, v
                total += self.n_heads * self.d_head * d         # o
            else:  # mamba1 block
                di, st = self.d_inner, self.ssm_state
                total += d * 2 * di          # in_proj (x, z)
                total += di * self.ssm_conv  # depthwise conv
                total += di * (self.dt_rank + 2 * st)  # x_proj
                total += self.dt_rank * di + di        # dt_proj (+bias)
                total += di * st + di                  # A_log, D
                total += di * d              # out_proj
            if ff == "dense":
                total += 3 * d * self.d_ff if self.act == "swiglu" \
                    else 2 * d * self.d_ff
            elif ff == "moe":
                total += d * self.n_experts  # router
                per = 3 * d * self.d_ff_expert if self.act == "swiglu" \
                    else 2 * d * self.d_ff_expert
                total += self.n_experts * per
            total += 2 * d if self.norm != "nonparam_ln" else 0
        total += d if self.norm != "nonparam_ln" else 0  # final norm
        return total

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: only top_k experts count)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        per_expert = (3 if self.act == "swiglu" else 2) * d * self.d_ff_expert
        inactive = 0
        for _, ff in self.pattern * self.n_groups:
            if ff == "moe":
                inactive += (self.n_experts - self.top_k) * per_expert
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def step_fn(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell (see DESIGN §6)."""
    if shape.name == "long_500k" and cfg.full_attention:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per assignment)"
        )
    return True, ""
