"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536
[arXiv:2403.19887; hf].  Period-8 pattern: one attention layer per 8
(position 4, as in the paper's figure), MoE every other layer; mamba mixer
elsewhere (d_inner=8192, state=16, dt_rank=256).  Runs long_500k: only 4
attention layers hold 500k KV; mamba layers are O(1)-state.
"""

from repro.configs.base import JAMBA_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab=65536,
        norm="rmsnorm",
        act="swiglu",
        n_experts=16,
        top_k=2,
        d_ff_expert=14336,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        dt_rank=256,
        pattern=JAMBA_PATTERN,
        source="[arXiv:2403.19887; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        family="hybrid",
        n_layers=8,   # one full period
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab=512,
        norm="rmsnorm",
        act="swiglu",
        n_experts=4,
        top_k=2,
        d_ff_expert=64,
        ssm_state=4,
        ssm_conv=4,
        ssm_expand=2,
        dt_rank=8,
        pattern=JAMBA_PATTERN,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
