"""musicgen-medium [audio]: decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec frontend is a stub: input_specs supplies precomputed
conditioning frame embeddings (prefix_len=64); the backbone decodes
EnCodec codebook tokens (vocab 2048).  MusicGen uses a vanilla transformer
(LayerNorm + GELU), not a llama-style block.
"""

from repro.configs.base import DENSE_PATTERN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab=2048,
        norm="layernorm",
        act="gelu",
        pattern=DENSE_PATTERN,
        frontend="audio",
        prefix_len=64,
        source="[arXiv:2306.05284; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="audio",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_head=12,
        d_ff=96,
        vocab=256,
        norm="layernorm",
        act="gelu",
        pattern=DENSE_PATTERN,
        frontend="audio",
        prefix_len=4,
        dtype="float32",
        ssm_chunk=8,
        head_pad_multiple=4,
        source="smoke",
    )
