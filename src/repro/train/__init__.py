from repro.train.step import (
    TrainState,
    init_train_state,
    make_train_step,
    make_prefill_step,
    make_serve_step,
    loss_fn,
)

__all__ = [
    "TrainState",
    "init_train_state",
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
    "loss_fn",
]
