"""Train / prefill / serve steps for every architecture.

These are the functions the dry-run lowers and the launcher drives.  All
three are pure (state in, state out): the cancellation/checkpoint machinery
wraps them at the host level, never reaches inside — the paper's
"flag tested between kernel executions" contract.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.frontends import prefix_embed_shape
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import lshard


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("params", "opt", "step", "rng"),
    meta_fields=(),
)
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict[str, Any]
    step: jax.Array
    rng: jax.Array


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    kp, kr = jax.random.split(key)
    params = lm.init_params(kp, cfg)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        step=jnp.zeros((), jnp.int32),
        rng=kr,
    )


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    params = lm.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.dtype == "bfloat16":
        opt["master"] = jax.tree.map(f32, params)
    return TrainState(
        params=params,
        opt=opt,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def train_state_axes(cfg: ModelConfig) -> TrainState:
    """Logical axes tree matching TrainState (for sharding resolution)."""
    axes = lm.param_axes(cfg)
    opt = {"mu": axes, "nu": axes, "count": ()}
    if cfg.dtype == "bfloat16":
        opt["master"] = axes
    return TrainState(params=axes, opt=opt, step=(), rng=(None,))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def _ce_terms(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(masked negative-log-likelihood sum, mask count) for one chunk.

    Sharded-vocab CE: logsumexp and the label contraction are plain
    reductions over the sharded axis (partial + all-reduce under GSPMD).
    take_along_axis/gather here would force a full-vocab all-gather of the
    logits (~13 GB/device at train_4k) — measured in EXPERIMENTS.md §Perf.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    label_mask = vocab_iota[None, None, :] == labels[..., None]
    label_logit = jnp.sum(jnp.where(label_mask, logits, 0.0), axis=-1)
    ll = label_logit - lse
    # final position predicts wrapped token (synthetic data) — keep it masked
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    return -jnp.sum(ll * mask), jnp.sum(mask)


def loss_fn(
    params: Any,
    tokens: jax.Array,        # (B, S_text)
    labels: jax.Array,        # (B, S_text) next-token targets
    cfg: ModelConfig,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, aux = lm.hidden_forward(params, tokens, cfg, prefix_embeds)
    x = x[:, -tokens.shape[1]:, :]  # prefix positions carry no labels
    b, s, d = x.shape

    nc = cfg.loss_chunk
    if nc and b % nc == 0 and b >= nc and nc > 1:
        # Chunked CE: the (B, S, vocab) f32 logits are never materialized;
        # each batch sub-chunk recomputes its logits in the backward pass.
        # Chunks are STRIDED (row = nc*j + i) so every chunk touches every
        # DP shard — a contiguous split would serialize onto single hosts.
        bc = b // nc
        xr = x.reshape(bc, nc, s, d).transpose(1, 0, 2, 3)
        lr = labels.reshape(bc, nc, s).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk(carry, inp):
            xc, lc = inp
            logits = lm.unembed(params, xc, cfg)
            nll, cnt = _ce_terms(logits, lc)
            return (carry[0] + nll, carry[1] + cnt), None

        (nll, cnt), _ = jax.lax.scan(
            chunk, (jnp.float32(0.0), jnp.float32(0.0)), (xr, lr)
        )
    else:
        logits = lm.unembed(params, x, cfg)
        nll, cnt = _ce_terms(logits, labels)

    ce = nll / jnp.maximum(cnt, 1.0)
    total = ce + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    schedule=None):
    """(state, batch) -> (state, metrics).  batch: dict of arrays."""
    schedule = schedule or (lambda s: 1.0)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (loss, parts), grads = grad_fn(
            state.params, batch["tokens"], batch["labels"], cfg,
            batch.get("prefix_embeds"),
        )
        lr_scale = schedule(state.step)
        params, opt, metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt, lr_scale
        )
        new_state = TrainState(
            params=params,
            opt=opt,
            step=state.step + 1,
            rng=jax.random.fold_in(state.rng, 0),
        )
        metrics = dict(metrics, loss=loss, **parts)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None):
    """(params, batch) -> (last-token logits, decode cache)."""

    def prefill(params, batch: Dict[str, jax.Array]):
        return lm.prefill_step(
            params, batch["tokens"], cfg, max_seq=max_seq,
            prefix_embeds=batch.get("prefix_embeds"),
        )

    return prefill


def make_serve_step(cfg: ModelConfig):
    """(params, cache, tokens (B,1), pos ()) -> (logits, cache)."""

    def serve(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, cfg)

    return serve


# ---------------------------------------------------------------------------
# Batch abstractions (shared by dry-run and drivers)
# ---------------------------------------------------------------------------


def train_batch_shapes(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStructs for one training batch (stub frontend included)."""
    s_text = seq - cfg.prefix_len
    shapes = {
        "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
    }
    pe = prefix_embed_shape(cfg, batch)
    if pe is not None:
        shapes["prefix_embeds"] = jax.ShapeDtypeStruct(pe, jnp.bfloat16)
    return shapes


def make_train_batch(key: jax.Array, cfg: ModelConfig, batch: int, seq: int):
    """Concrete synthetic batch matching train_batch_shapes."""
    from repro.data.tokens import synthetic_token_batch
    from repro.models.frontends import synthetic_prefix

    s_text = seq - cfg.prefix_len
    tb = synthetic_token_batch(key, batch=batch, seq=s_text, vocab=cfg.vocab)
    out = {"tokens": tb.tokens, "labels": tb.labels}
    pe = synthetic_prefix(jax.random.fold_in(key, 1), cfg, batch)
    if pe is not None:
        out["prefix_embeds"] = pe
    return out
