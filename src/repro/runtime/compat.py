"""Version-tolerant jax API aliases (the shard_map move + kwarg rename).

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
Callers in this repo import :func:`shard_map` from here and always spell the
kwarg ``check_vma``; the shim maps it onto whatever the installed jax expects
— the same survive-version-bumps discipline as
:mod:`repro.kernels._compat` for Pallas compiler params.
"""

from __future__ import annotations

from typing import Any

import jax

try:  # jax >= 0.6: public API, check_vma kwarg
    from jax import shard_map as _shard_map

    _MODERN = True
except ImportError:  # older jax: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _MODERN = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True) -> Any:
    if _MODERN:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    # Old jax cannot express device-varying typing (no pvary), so its
    # check_rep static analysis rejects valid ring collectives — disable it;
    # the check never affects numerics.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def axis_size(axis: str) -> int:
    """Static mapped-axis size inside shard_map (old jax: the psum idiom)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def pvary(x, axis: str):
    """Mark a constant device-varying over ``axis`` (no-op on old jax,
    which has no varying-manual-axes typing to satisfy)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, (axis,))
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, (axis,), to="varying")
    return x
