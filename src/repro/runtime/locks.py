"""Reentrant writer-preferred reader/writer lock.

Direct port of the synchronization design in the paper (§II.A):

    "We use a self implemented reentrant writer preferred RW lock. [...] As
    the lock prefers the writers, from the moment a writer is waiting, all
    new readers have to queue up. After the readers, that already have
    acquired the lock when the writer arrived, have released the lock again,
    the writer can change the value of the flag. [...] After the writer has
    released the writer lock, all waiting readers see the new value."

In the paper the lock guards (a) the load state of the dynamically loaded
OpenCL library and (b) the cooperative abort flag polled between kernel
executions.  Here it guards (a) backend load state and (b) the cancellation
token polled between jitted steps (see :mod:`repro.core.cancellation`).

Properties implemented (and asserted in tests/test_locks.py):

- multiple concurrent readers;
- writer exclusion (no readers or other writers while held);
- *writer preference*: once a writer is waiting, newly arriving readers block
  until the writer has acquired and released;
- *reentrancy*: a thread may re-acquire a lock it already holds (read-in-read,
  write-in-write, and read-in-write downgrade-style nesting);
- a thread holding the write lock may take the read lock without deadlock.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator


class RWLock:
    """Reentrant writer-preferred reader/writer lock."""

    def __init__(self) -> None:
        self._cond = threading.Condition(threading.Lock())
        # per-thread read recursion counts (thread id -> count)
        self._readers: Dict[int, int] = {}
        self._writer: int | None = None  # thread id of current writer
        self._writer_recursion = 0
        self._writers_waiting = 0

    # -- introspection helpers (used by tests and the watchdog) ------------

    @property
    def readers(self) -> int:
        with self._cond:
            return sum(1 for c in self._readers.values() if c > 0)

    @property
    def writer_active(self) -> bool:
        with self._cond:
            return self._writer is not None

    @property
    def writers_waiting(self) -> int:
        with self._cond:
            return self._writers_waiting

    # -- read side ----------------------------------------------------------

    def acquire_read(self, timeout: float | None = None) -> bool:
        me = threading.get_ident()
        with self._cond:
            # Reentrant fast paths: already a reader, or we ARE the writer
            # (a writer may read its own protected state).
            if self._readers.get(me, 0) > 0 or self._writer == me:
                self._readers[me] = self._readers.get(me, 0) + 1
                return True
            # Writer preference: block while a writer is active OR waiting.
            ok = self._cond.wait_for(
                lambda: self._writer is None and self._writers_waiting == 0,
                timeout=timeout,
            )
            if not ok:
                return False
            self._readers[me] = 1
            return True

    def release_read(self) -> None:
        me = threading.get_ident()
        with self._cond:
            count = self._readers.get(me, 0)
            if count <= 0:
                raise RuntimeError("release_read without matching acquire_read")
            if count == 1:
                del self._readers[me]
            else:
                self._readers[me] = count - 1
            self._cond.notify_all()

    # -- write side ----------------------------------------------------------

    def acquire_write(self, timeout: float | None = None) -> bool:
        me = threading.get_ident()
        with self._cond:
            if self._writer == me:  # write-in-write reentrancy
                self._writer_recursion += 1
                return True
            self._writers_waiting += 1
            try:
                # Wait until no other writer and no reader other than us holds it.
                def _free() -> bool:
                    others_reading = any(
                        tid != me and c > 0 for tid, c in self._readers.items()
                    )
                    return self._writer is None and not others_reading

                ok = self._cond.wait_for(_free, timeout=timeout)
                if not ok:
                    return False
                self._writer = me
                self._writer_recursion = 1
                return True
            finally:
                self._writers_waiting -= 1

    def release_write(self) -> None:
        me = threading.get_ident()
        with self._cond:
            if self._writer != me:
                raise RuntimeError("release_write by non-owning thread")
            self._writer_recursion -= 1
            if self._writer_recursion == 0:
                self._writer = None
                self._cond.notify_all()

    # -- context managers -----------------------------------------------------

    @contextmanager
    def read(self) -> Iterator[None]:
        self.acquire_read()
        try:
            yield
        finally:
            self.release_read()

    @contextmanager
    def write(self) -> Iterator[None]:
        self.acquire_write()
        try:
            yield
        finally:
            self.release_write()
