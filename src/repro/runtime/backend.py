"""Lazy backend discovery — the "wrapper library" layer.

The paper's wrapper library `dlopen`s the vendor OpenCL `.so` at runtime,
resolves symbols lazily immediately before first use, returns an error code
when called before load, and can be unloaded/reloaded.  The JAX analogue of
"do not link the accelerator at compile time" is: **never touch jax device
state at import time**.  This module keeps all device queries behind an
explicit :func:`load` / :func:`discover_backend` call guarded by the same
writer-preferred RW lock the paper uses for its load-state flag.

Why this matters here concretely: ``launch/dryrun.py`` must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax device query process-wide.  Any module that calls ``jax.devices()`` at
import time would lock the device count at 1 and silently break the
multi-pod dry-run — the exact class of bug the paper's lazy-loading design
exists to prevent (calling an OpenCL symbol before the library is loaded).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional

from repro.runtime.locks import RWLock


class BackendNotLoadedError(RuntimeError):
    """Raised when a backend query is made before :func:`load`.

    Mirrors the paper: "If an OpenCL method of the wrapper library is called
    before the shared library has been loaded [...] an error is returned."
    """


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Peak-rate card for one accelerator chip (roofline constants)."""

    name: str
    peak_bf16_flops: float  # FLOP/s
    hbm_bandwidth: float    # byte/s
    ici_link_bandwidth: float  # byte/s per link
    hbm_bytes: int
    vmem_bytes: int


# TPU v5e: the compile target for every kernel and dry-run in this repo.
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# The host we actually run on (correctness/interpret mode only).
HOST_CPU = ChipSpec(
    name="host_cpu",
    peak_bf16_flops=1e11,
    hbm_bandwidth=1e10,
    ici_link_bandwidth=1e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=32 * 1024**2,
)


@dataclasses.dataclass
class Backend:
    """A loaded accelerator backend."""

    platform: str
    device_count: int
    devices: List[Any]
    chip: ChipSpec

    @property
    def is_tpu(self) -> bool:
        return self.platform == "tpu"


class _BackendRegistry:
    """Process-wide backend state behind the paper's RW lock discipline."""

    def __init__(self) -> None:
        self._lock = RWLock()
        self._backend: Optional[Backend] = None
        self._load_count = 0  # diagnostics: how many load/unload cycles

    def load(self) -> Backend:
        """Discover devices now (first jax device query happens here)."""
        with self._lock.write():
            if self._backend is None:
                import jax  # local import: keep module import side-effect free

                devices = jax.devices()
                platform = devices[0].platform
                chip = TPU_V5E if platform == "tpu" else HOST_CPU
                self._backend = Backend(
                    platform=platform,
                    device_count=len(devices),
                    devices=list(devices),
                    chip=chip,
                )
                self._load_count += 1
            return self._backend

    def unload(self) -> None:
        """Forget the backend (paper: library can be unloaded at runtime).

        jax itself keeps its client alive; this resets *our* view so tests can
        exercise the call-before-load error path.
        """
        with self._lock.write():
            self._backend = None

    def get(self) -> Backend:
        with self._lock.read():
            if self._backend is None:
                raise BackendNotLoadedError(
                    "backend not loaded; call repro.runtime.backend.load() first"
                )
            return self._backend

    @property
    def loaded(self) -> bool:
        with self._lock.read():
            return self._backend is not None

    @property
    def load_count(self) -> int:
        with self._lock.read():
            return self._load_count


_REGISTRY = _BackendRegistry()


def load() -> Backend:
    return _REGISTRY.load()


def unload() -> None:
    _REGISTRY.unload()


def get_backend() -> Backend:
    return _REGISTRY.get()


def discover_backend() -> Backend:
    """Load-if-needed and return the backend (the common entry point)."""
    return _REGISTRY.load()


def is_loaded() -> bool:
    return _REGISTRY.loaded
