"""Preemption handling — the activity-lifecycle contract at cluster scale.

Paper: the Android OS may suspend the activity at any moment; jobs must
terminate "timely" (a few seconds) and release accelerator resources in an
ordered manner, and a *partial wake lock* keeps the CPU running while the
screen is allowed to turn off.

Cluster translation:
- SIGTERM/SIGINT (preemption notice from the scheduler) -> cancel the shared
  :class:`CancellationToken` with reason PREEMPTION; the training/clustering
  loop observes it at the next step boundary, writes a checkpoint, marks the
  job SUSPENDED and exits cleanly;
- :class:`HoldAlive` is the wake-lock analogue: while held, the job renews
  its heartbeat in the job store so the recovery sweep of other launchers
  never mistakes a live-but-slow job for an orphan.
"""

from __future__ import annotations

import signal
import threading
import time
from types import FrameType
from typing import Optional

from repro.core.cancellation import CancellationToken, CancelReason
from repro.core.jobs import JobStore


class PreemptionGuard:
    """Routes SIGTERM/SIGINT into cooperative cancellation.

    Second signal while already cancelling re-raises the default behaviour
    (the paper's 'app would be reported not responding' deadline, inverted:
    we give the operator a hard-exit escape hatch).
    """

    def __init__(self, token: CancellationToken,
                 signals=(signal.SIGTERM, signal.SIGINT)) -> None:
        self.token = token
        self.signals = signals
        self._old = {}
        self._fired = False

    def _handler(self, signum: int, frame: Optional[FrameType]) -> None:
        if self._fired:
            # restore + re-raise: hard exit on the second signal
            signal.signal(signum, self._old.get(signum, signal.SIG_DFL))
            signal.raise_signal(signum)
            return
        self._fired = True
        self.token.cancel(CancelReason.PREEMPTION)

    def __enter__(self) -> "PreemptionGuard":
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, old in self._old.items():
            signal.signal(s, old)
        self._old.clear()


class HoldAlive:
    """Wake-lock analogue: heartbeat the job store while the job computes."""

    def __init__(self, store: JobStore, job_id: int,
                 interval: float = 5.0) -> None:
        self.store = store
        self.job_id = job_id
        self.interval = interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            self.store.report_progress(self.job_id)

    def __enter__(self) -> "HoldAlive":
        self.store.report_progress(self.job_id)  # immediate first beat
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.interval + 1.0)
