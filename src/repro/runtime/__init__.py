"""Runtime substrate: the TPU-side analogue of the paper's OpenCL wrapper library.

The paper's wrapper discovers and loads the vendor OpenCL library lazily at
runtime, guards its load state with a writer-preferred reentrant RW lock, and
lets long-running GPU jobs be aborted cooperatively between kernel launches.

Here the same responsibilities map to:

- :mod:`repro.runtime.backend`   -- lazy device/capability discovery
- :mod:`repro.runtime.locks`     -- the RW lock (direct port)
- :mod:`repro.runtime.preemption`-- SIGTERM -> checkpoint-and-exit, hold-alive
- :mod:`repro.runtime.watchdog`  -- step-time straggler watchdog
"""

from repro.runtime.locks import RWLock
from repro.runtime.backend import Backend, discover_backend

__all__ = ["RWLock", "Backend", "discover_backend"]
