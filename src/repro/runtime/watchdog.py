"""Step-time watchdog — straggler mitigation at the job level.

At pod scale a single slow host (thermal throttling, failing HBM, a noisy
neighbor) stretches every synchronous step.  The watchdog tracks a robust
running estimate of step time; when the *current* step exceeds
``factor x median`` it fires a callback — by default flagging the job so the
controller can checkpoint and reschedule (cancel with reason WATCHDOG),
mirroring the paper's requirement that a stuck computation must never block
the UI thread for more than a few seconds.
"""

from __future__ import annotations

import statistics
import threading
import time
from typing import Callable, List, Optional


class StepWatchdog:
    def __init__(
        self,
        on_straggler: Callable[[float, float], None],
        *,
        factor: float = 3.0,
        min_samples: int = 5,
        poll_interval: float = 0.05,
    ) -> None:
        self.on_straggler = on_straggler
        self.factor = factor
        self.min_samples = min_samples
        self.poll_interval = poll_interval
        self._durations: List[float] = []
        self._step_start: Optional[float] = None
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._fired_for_current = False
        self._thread: Optional[threading.Thread] = None
        self.straggler_events = 0

    # -- step instrumentation (called from the training loop) ---------------

    def step_begin(self) -> None:
        with self._lock:
            self._step_start = time.monotonic()
            self._fired_for_current = False

    def step_end(self) -> None:
        with self._lock:
            if self._step_start is not None:
                self._durations.append(time.monotonic() - self._step_start)
                if len(self._durations) > 256:
                    self._durations = self._durations[-128:]
            self._step_start = None

    @property
    def median(self) -> Optional[float]:
        with self._lock:
            if len(self._durations) < self.min_samples:
                return None
            return statistics.median(self._durations)

    # -- monitor thread -----------------------------------------------------------

    def _run(self) -> None:
        while not self._stop.wait(self.poll_interval):
            med = self.median
            with self._lock:
                start = self._step_start
                fired = self._fired_for_current
            if med is None or start is None or fired:
                continue
            elapsed = time.monotonic() - start
            if elapsed > self.factor * med:
                with self._lock:
                    self._fired_for_current = True
                    self.straggler_events += 1
                self.on_straggler(elapsed, med)

    def __enter__(self) -> "StepWatchdog":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
