"""Mamba-1 selective SSM block (falcon-mamba, jamba mixer).

TPU adaptation notes (the paper-mapping discipline of DESIGN.md §2 applied
to this substrate): the original Mamba CUDA kernel is a hardware-aware
recurrence that keeps h in SRAM.  The TPU-native equivalent used here:

- the recurrence h_t = a_t * h_{t-1} + b_t (a_t = exp(dt_t * A), diagonal A)
  is a first-order linear recurrence, computed with
  `jax.lax.associative_scan` *within chunks* of ssm_chunk tokens and a
  `lax.scan` carrying h across chunks.  This bounds the materialized state
  tensor to (B, chunk, d_inner, d_state) — the VMEM-residency argument of
  the CUDA kernel, restated as a chunking schedule for XLA;
- chunk bodies are rematerialized in the backward pass (jax.checkpoint), so
  training memory stays O(B * L * d_inner) for activations, not
  O(B * L * d_inner * d_state);
- decode is the O(1) recurrence step on a carried (conv_state, ssm_state)
  cache — the reason the long_500k cell is runnable for SSM archs at all.

Parameter shapes follow mamba-1: in_proj fused (x,z), depthwise causal
conv (k=4), x_proj -> (dt_rank, B, C), dt_proj with softplus bias init,
A_log initialized to log(1..d_state), D skip, out_proj.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.declare import DeclTree, ParamDecl
from repro.parallel.sharding import lshard


def mamba_decls(cfg: ModelConfig) -> DeclTree:
    d, di, st, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    conv = cfg.ssm_conv

    def a_log_init(key, shape, dtype):
        # S4D-real init: A = -(1..d_state) per channel; shape-general so the
        # stacked (layers, di, st) declaration initializes correctly too
        a = jnp.broadcast_to(
            jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape
        )
        return jnp.log(a).astype(jnp.float32)  # kept f32 (sensitive)

    return {
        "in_proj": ParamDecl((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamDecl((conv, di), ("conv_kernel", "ssm_inner"),
                            "fan_in", scale=1.0),
        "conv_b": ParamDecl((di,), ("ssm_inner",), "zeros"),
        "x_proj": ParamDecl((di, dtr + 2 * st), ("ssm_inner", None)),
        "dt_proj": ParamDecl((dtr, di), ("dt_rank", "ssm_inner"),
                             scale=dtr ** -0.5),
        "dt_bias": ParamDecl(
            (di,), ("ssm_inner",), "custom",
            custom=lambda key, shape, dtype: _dt_bias_init(key, shape),
            dtype="float32",
        ),
        "a_log": ParamDecl((di, st), ("ssm_inner", "ssm_state"), "custom",
                           custom=a_log_init, dtype="float32"),
        "d_skip": ParamDecl((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamDecl((di, d), ("ssm_inner", "embed")),
    }


def _dt_bias_init(key, shape):
    # dt in [1e-3, 1e-1] via inverse softplus (mamba reference init)
    dt = jnp.exp(
        jax.random.uniform(key, shape, jnp.float32)
        * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    return jnp.log(jnp.expm1(dt)).astype(jnp.float32)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq.  x: (B, L, di); w: (K, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted slices: cheap, fusion-friendly for small K (=4)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssm_inputs(params: Dict, xc: jax.Array, cfg: ModelConfig):
    """Shared by scan/decode: per-token (a, bx, C) from conv output xc."""
    dtr, st = cfg.dt_rank, cfg.ssm_state
    proj = jnp.einsum("...i,ij->...j", xc, params["x_proj"].astype(xc.dtype))
    dt_raw, B, C = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_raw, params["dt_proj"].astype(xc.dtype))
        .astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # (..., di) f32
    a_mat = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, st)
    a = jnp.exp(dt[..., None] * a_mat)                     # (..., di, st)
    bx = (dt * xc.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[
        ..., None, :
    ]  # (..., di, st)
    return a, bx, C.astype(jnp.float32)


def _scan_chunk(h0, a, bx):
    """Linear recurrence over one chunk via associative scan.
    a, bx: (L, B, di, st); h0: (B, di, st)."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=0)
    h = a_cum * h0[None] + b_cum
    return h  # (L, B, di, st)


def mamba_block(params: Dict, x: jax.Array, cfg: ModelConfig,
                return_state: bool = False):
    """Training/prefill forward.  x: (B, L, d) -> (B, L, d).

    ``return_state=True`` additionally returns (conv_state, ssm_state) for
    handing off to decode (prefill path) — computed in the SAME pass.
    """
    b, l, _ = x.shape
    di = cfg.d_inner
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, [di], axis=-1)
    xs = lshard(xs, "batch", "seq", "ssm_inner")
    xc = jax.nn.silu(
        _causal_conv(xs, params["conv_w"].astype(x.dtype),
                     params["conv_b"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)

    chunk = min(cfg.ssm_chunk, l)
    if return_state and l % chunk != 0:
        chunk = l  # single chunk: padding would contaminate the carried state
    l_pad = -(-l // chunk) * chunk  # causal: end-padding never leaks back
    n_chunks = l_pad // chunk
    if l_pad != l:
        xc_p = jnp.pad(xc, ((0, 0), (0, l_pad - l), (0, 0)))
    else:
        xc_p = xc
    # (n_chunks, chunk, B, di) for the outer scan
    xcc = xc_p.reshape(b, n_chunks, chunk, di).transpose(1, 2, 0, 3)

    # The selective-scan inputs (a, bx ~ (chunk, B, di, st)) and the
    # y = h . C contraction both live INSIDE the chunk body: nothing of
    # size d_state x L is ever materialized for the whole layer, and the
    # backward recomputes per chunk (jax.checkpoint).  This is the TPU
    # restatement of the Mamba CUDA kernel's SRAM-residency argument.
    @jax.checkpoint
    def chunk_body(h0, xc_chunk):
        ac, bc, cc = _ssm_inputs(params, xc_chunk, cfg)
        h = _scan_chunk(h0, ac, bc)            # (chunk, B, di, st)
        yc = jnp.einsum("lbis,lbs->lbi", h, cc)
        return h[-1], yc

    h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_body, h0, xcc)
    # ys: (n_chunks, chunk, B, di) -> (B, L, di)
    y = ys.transpose(2, 0, 1, 3).reshape(b, l_pad, di)[:, :l]
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :] * xc.astype(
        jnp.float32
    )
    y = (y.astype(x.dtype)) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bli,id->bld", y, params["out_proj"].astype(x.dtype))
    out = lshard(out, "batch", "seq_sp", "embed")
    if return_state:
        conv_state = xs[:, l - (cfg.ssm_conv - 1):, :]  # trailing K-1 inputs
        return out, conv_state, h_last
    return out


def mamba_decode_step(
    params: Dict,
    x: jax.Array,             # (B, 1, d)
    cfg: ModelConfig,
    conv_state: jax.Array,    # (B, K-1, di) trailing conv inputs
    ssm_state: jax.Array,     # (B, di, st) f32
):
    """O(1) single-token step; returns (y (B,1,d), conv_state, ssm_state)."""
    di = cfg.d_inner
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, [di], axis=-1)        # (B, 1, di)

    # conv over [conv_state, xs]
    w = params["conv_w"].astype(x.dtype)        # (K, di)
    window = jnp.concatenate([conv_state, xs], axis=1)  # (B, K, di)
    xc = jnp.einsum("bki,ki->bi", window, w) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)  # (B, di)
    conv_state = window[:, 1:, :]

    a, bx, C = _ssm_inputs(params, xc, cfg)     # (B, di, st), (B, st)
    ssm_state = a * ssm_state + bx              # (B, di, st) f32
    y = jnp.einsum("bis,bs->bi", ssm_state, C)
    y = y + params["d_skip"].astype(jnp.float32)[None, :] * xc.astype(
        jnp.float32
    )
    y = y.astype(x.dtype) * jax.nn.silu(
        z[:, 0].astype(jnp.float32)
    ).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"].astype(x.dtype))
    return out[:, None, :], conv_state, ssm_state
