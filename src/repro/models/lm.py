"""The unified decoder: every assigned architecture is an instance of this.

One scan period = ``cfg.pattern`` sub-layers (attn/mamba mixer + optional
dense/MoE FFN).  Parameters for one period are stacked over
``cfg.n_groups`` and the stack is consumed by ``lax.scan`` — compile time
and HLO size are O(period), not O(n_layers), which is what makes 64 dry-run
compiles on one CPU core feasible (and is the right structure on real pods
too: one program per unique layer).

Entry points:
- :func:`forward`       — training/prefill logits (+ aux losses)
- :func:`prefill_step`  — forward AND build the decode cache
- :func:`decode_step`   — one-token step against the cache
- :func:`init_params` / :func:`abstract_params` / :func:`param_axes` —
  concrete init, dry-run ShapeDtypeStructs, and logical sharding axes, all
  from the same declarations.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import declare
from repro.models.declare import DeclTree, ParamDecl
from repro.models.layers import (
    apply_norm,
    attention,
    attention_decode,
    attention_decls,
    mlp,
    mlp_decls,
    norm_decls,
)
from repro.models.mamba import (
    mamba_block,
    mamba_decls,
    mamba_decode_step,
)
from repro.models.moe import moe_decls, moe_ffn
from repro.parallel.sharding import lshard

DecodeCache = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------


def _sub_decls(cfg: ModelConfig, mixer: str, ff: Optional[str]) -> DeclTree:
    d: DeclTree = {"norm1": norm_decls(cfg)}
    if mixer == "attn":
        d["attn"] = attention_decls(cfg)
    else:
        d["mamba"] = mamba_decls(cfg)
    if ff == "dense":
        d["norm2"] = norm_decls(cfg)
        d["mlp"] = mlp_decls(cfg)
    elif ff == "moe":
        d["norm2"] = norm_decls(cfg)
        d["moe"] = moe_decls(cfg)
    return d


def model_decls(cfg: ModelConfig) -> DeclTree:
    group: DeclTree = {
        f"sub_{i}": _sub_decls(cfg, mixer, ff)
        for i, (mixer, ff) in enumerate(cfg.pattern)
    }
    stacked = jax.tree_util.tree_map(
        lambda p: declare.stack_layers(p, cfg.n_groups),
        group,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )
    decls: DeclTree = {
        "embed": ParamDecl((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                           "normal", scale=0.02),
        "layers": stacked,
        "final_norm": norm_decls(cfg),
    }
    if not cfg.tie_embeddings:
        decls["lm_head"] = ParamDecl(
            (cfg.d_model, cfg.vocab_padded), ("embed", "vocab")
        )
    return decls


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    return declare.init_tree(key, model_decls(cfg), _dtype(cfg))


def abstract_params(cfg: ModelConfig) -> Dict:
    return declare.abstract_tree(model_decls(cfg), _dtype(cfg))


def param_axes(cfg: ModelConfig) -> Dict:
    return declare.axes_tree(model_decls(cfg))


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------


def _apply_sub(
    sub: Dict, x: jax.Array, cfg: ModelConfig, idx: int, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    mixer, ff = cfg.pattern[idx]
    h = apply_norm(sub.get("norm1", {}), x, cfg)
    if mixer == "attn":
        y = attention(sub["attn"], h, cfg, positions)
    else:
        y = mamba_block(sub["mamba"], h, cfg)
    x = x + y
    aux = jnp.float32(0.0)
    if ff is not None:
        h = apply_norm(sub.get("norm2", {}), x, cfg)
        if ff == "dense":
            y = mlp(sub["mlp"], h, cfg)
        else:
            y, aux = moe_ffn(sub["moe"], h, cfg)
        x = x + y
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    return jax.checkpoint(fn)  # "full": save only layer boundaries


# ---------------------------------------------------------------------------
# Forward (training / prefill logits)
# ---------------------------------------------------------------------------


def _embed_tokens(params: Dict, tokens: jax.Array, cfg: ModelConfig):
    emb = params["embed"]
    x = emb[tokens].astype(_dtype(cfg))
    return lshard(x, "batch", "seq_sp", "embed")


def _logits(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        # mask padded vocab columns: exact published-model semantics
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return lshard(logits, "batch", "seq", "vocab")


def hidden_forward(
    params: Dict,
    tokens: jax.Array,                      # (B, S_text) int32
    cfg: ModelConfig,
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, d) stub frontend
) -> Tuple[jax.Array, jax.Array]:
    """Returns (final normed hidden states (B, S, d), aux_loss ())."""
    x = _embed_tokens(params, tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    positions = jnp.arange(seq, dtype=jnp.int32)

    def _sub_fn(i):
        def f(sub, h, pos):
            return _apply_sub(sub, h, cfg, i, pos)

        if cfg.remat == "full" and cfg.period > 1:
            # nested remat: the backward of a heterogeneous group otherwise
            # holds all `period` sub-layers' recompute graphs live at once
            # (measured 154 GiB/chip on jamba train_4k — §Perf)
            return jax.checkpoint(f)
        return f

    sub_fns = [_sub_fn(i) for i in range(cfg.period)]

    def group_body(carry, group_params):
        h, aux = carry
        for i in range(cfg.period):
            h, a = sub_fns[i](group_params[f"sub_{i}"], h, positions)
            aux = aux + a
        return (h, aux), None

    body = _remat(group_body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"])
    else:
        aux = jnp.float32(0.0)
        for g in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda p: p[g], params["layers"])
            (x, aux), _ = body((x, aux), gp)

    x = apply_norm(params.get("final_norm", {}), x, cfg)
    return x, aux


def forward(
    params: Dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, vocab_padded) f32, aux_loss ())."""
    x, aux = hidden_forward(params, tokens, cfg, prefix_embeds)
    return _logits(params, x, cfg), aux


def unembed(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Public logits head (used by the chunked loss)."""
    return _logits(params, x, cfg)


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


def _sub_cache_decls(cfg: ModelConfig, mixer: str, batch: int, max_seq: int):
    dt = _dtype(cfg)
    if mixer == "attn":
        kv_shape = (batch, max_seq, cfg.n_kv_heads_padded, cfg.d_head)
        axes = ("batch", "seq_kv", "kv_heads", "head_dim")
        return {
            "k": ParamDecl(kv_shape, axes, "zeros"),
            "v": ParamDecl(kv_shape, axes, "zeros"),
        }
    return {
        "conv": ParamDecl((batch, cfg.ssm_conv - 1, cfg.d_inner),
                          ("batch", None, "ssm_inner"), "zeros"),
        "ssm": ParamDecl((batch, cfg.d_inner, cfg.ssm_state),
                         ("batch", "ssm_inner", "ssm_state"), "zeros"),
    }


def cache_decls(cfg: ModelConfig, batch: int, max_seq: int) -> DeclTree:
    group = {
        f"sub_{i}": _sub_cache_decls(cfg, mixer, batch, max_seq)
        for i, (mixer, _) in enumerate(cfg.pattern)
    }
    return jax.tree_util.tree_map(
        lambda p: declare.stack_layers(p, cfg.n_groups),
        group,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int) -> DecodeCache:
    # NOTE: ssm states are f32 (recurrence numerics); kv caches model dtype.
    decls = cache_decls(cfg, batch, max_seq)

    def make(d: ParamDecl):
        dt = jnp.float32 if d.axes[-1] == "ssm_state" else _dtype(cfg)
        return jnp.zeros(d.shape, dt)

    return jax.tree_util.tree_map(
        make, decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )


def abstract_decode_cache(cfg: ModelConfig, batch: int, max_seq: int):
    decls = cache_decls(cfg, batch, max_seq)

    def make(d: ParamDecl):
        dt = jnp.float32 if d.axes[-1] == "ssm_state" else _dtype(cfg)
        return jax.ShapeDtypeStruct(d.shape, dt)

    return jax.tree_util.tree_map(
        make, decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )


def cache_axes(cfg: ModelConfig, batch: int, max_seq: int):
    return declare.axes_tree(cache_decls(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(
    params: Dict,
    cache: DecodeCache,
    tokens: jax.Array,    # (B, 1) int32
    pos: jax.Array,       # () int32 — position being written
    cfg: ModelConfig,
) -> Tuple[jax.Array, DecodeCache]:
    """One-token decode.  Returns (logits (B, 1, vocab), updated cache)."""
    x = _embed_tokens(params, tokens, cfg)

    def group_body(h, xs):
        gp, gc = xs
        new_gc = {}
        for i, (mixer, ff) in enumerate(cfg.pattern):
            sub, sc = gp[f"sub_{i}"], gc[f"sub_{i}"]
            hn = apply_norm(sub.get("norm1", {}), h, cfg)
            if mixer == "attn":
                y, k, v = attention_decode(sub["attn"], hn, cfg,
                                           sc["k"], sc["v"], pos)
                new_gc[f"sub_{i}"] = {"k": k, "v": v}
            else:
                y, conv, ssm = mamba_decode_step(sub["mamba"], hn, cfg,
                                                 sc["conv"], sc["ssm"])
                new_gc[f"sub_{i}"] = {"conv": conv, "ssm": ssm}
            h = h + y
            if ff is not None:
                hn = apply_norm(sub.get("norm2", {}), h, cfg)
                if ff == "dense":
                    y = mlp(sub["mlp"], hn, cfg)
                else:
                    y, _ = moe_ffn(sub["moe"], hn, cfg, no_drop=True)
                h = h + y
        return h, new_gc

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache))
    else:  # unrolled (analysis mode: exact HLO cost accounting)
        new_gcs = []
        for g in range(cfg.n_groups):
            take = lambda t: jax.tree_util.tree_map(lambda p: p[g], t)
            x, gc = group_body(x, (take(params["layers"]), take(cache)))
            new_gcs.append(gc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_gcs
        )
    x = apply_norm(params.get("final_norm", {}), x, cfg)
    return _logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# Prefill (forward + cache construction)
# ---------------------------------------------------------------------------


def prefill_step(
    params: Dict,
    tokens: jax.Array,                      # (B, S_text)
    cfg: ModelConfig,
    max_seq: Optional[int] = None,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, DecodeCache]:
    """Forward over the prompt, returning (last-position logits, cache).

    The cache is sized ``max_seq`` (>= prompt length) so decode can continue
    in place.  Mamba sub-layers cache (conv tail, final h); attention caches
    the full K/V prefix.
    """
    from repro.models.layers import _qkv  # local: shares rope/proj path

    b, s_text = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    max_seq = max_seq or seq
    assert max_seq >= seq
    positions = jnp.arange(seq, dtype=jnp.int32)

    def group_body(h, gp):
        new_gc = {}
        for i, (mixer, ff) in enumerate(cfg.pattern):
            sub = gp[f"sub_{i}"]
            hn = apply_norm(sub.get("norm1", {}), h, cfg)
            if mixer == "attn":
                q, k, v = _qkv(sub["attn"], hn, cfg, positions)
                from repro.models.layers import _sdpa, _sdpa_chunked

                if cfg.attn_chunk and seq > cfg.attn_chunk:
                    o = _sdpa_chunked(q, k, v, cfg, cfg.attn_chunk)
                else:
                    o = _sdpa(q, k, v, cfg)
                y = jnp.einsum("bshk,hkd->bsd", o,
                               sub["attn"]["wo"].astype(h.dtype))
                pad = max_seq - seq
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_gc[f"sub_{i}"] = {
                    "k": lshard(kc, "batch", "seq_kv", "kv_heads", "head_dim"),
                    "v": lshard(vc, "batch", "seq_kv", "kv_heads", "head_dim"),
                }
            else:
                y, conv_st, ssm_st = _mamba_prefill(sub["mamba"], hn, cfg)
                new_gc[f"sub_{i}"] = {"conv": conv_st, "ssm": ssm_st}
            h = h + y
            if ff is not None:
                hn = apply_norm(sub.get("norm2", {}), h, cfg)
                if ff == "dense":
                    y = mlp(sub["mlp"], hn, cfg)
                else:
                    y, _ = moe_ffn(sub["moe"], hn, cfg)
                h = h + y
        return h, new_gc

    if cfg.scan_layers:
        x, cache = jax.lax.scan(group_body, x, params["layers"])
    else:
        gcs = []
        for g in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda p: p[g], params["layers"])
            x, gc = group_body(x, gp)
            gcs.append(gc)
        cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *gcs)
    x = apply_norm(params.get("final_norm", {}), x, cfg)
    logits = _logits(params, x[:, -1:, :], cfg)
    return logits, cache


def _mamba_prefill(sub: Dict, x: jax.Array, cfg: ModelConfig):
    """Mamba forward returning decode states — single pass (no duplicate
    recompute graph; the old two-pass version held both alive and doubled
    prefill transients — §Perf)."""
    return mamba_block(sub, x, cfg, return_state=True)
