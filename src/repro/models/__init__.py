from repro.models.lm import (
    DecodeCache,
    init_params,
    param_axes,
    forward,
    init_decode_cache,
    decode_step,
)

__all__ = [
    "DecodeCache",
    "init_params",
    "param_axes",
    "forward",
    "init_decode_cache",
    "decode_step",
]
