"""Mixture-of-Experts FFN: top-k token-choice routing, sort-based dispatch.

Design (TPU-minded):
- **Sort-based dispatch** instead of the (tokens, experts, capacity) one-hot
  einsum: token->expert pairs are argsorted by expert id, given a
  position-in-expert by a cumulative count, capacity-dropped, and scattered
  into a dense (E, C, d) buffer.  Memory is O(E*C*d) = O(cf * T * k * d / E
  * E) = O(cf*k*T*d) — the true activation volume — versus O(T*E*C) for the
  dispatch-mask formulation, which explodes for (64 experts, top-8) OLMoE.
- The expert matmuls are a single batched einsum over the expert axis, which
  shards cleanly over "expert" -> "model" (EP); GSPMD turns the
  scatter/gather across (data-sharded tokens) x (expert-sharded buffers)
  into the expected all-to-alls.
- Capacity-dropped tokens pass through the residual (standard top-k
  semantics); an auxiliary load-balance loss (Switch-style) is returned for
  the trainer.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.declare import DeclTree, ParamDecl
from repro.parallel.sharding import lshard


def moe_decls(cfg: ModelConfig) -> DeclTree:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    decls: DeclTree = {
        "router": ParamDecl((d, e), ("embed", "expert"), scale=0.1),
        "w_up": ParamDecl((e, d, f), ("expert", "embed", "ff")),
        "w_down": ParamDecl((e, f, d), ("expert", "ff", "embed")),
    }
    if cfg.act == "swiglu":
        decls["w_gate"] = ParamDecl((e, d, f), ("expert", "embed", "ff"))
    return decls


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * n_tokens * cfg.top_k
                      / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 (VPU sublane)


def moe_ffn(
    params: Dict, x: jax.Array, cfg: ModelConfig, *, no_drop: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss ()).

    ``no_drop=True`` sizes capacity at T*k (worst case) so no token is ever
    dropped — inference semantics, used by decode_step where T is tiny.
    Training keeps the capacity-dropped semantics (dropped tokens ride the
    residual), which is why train-forward and decode logits can differ at
    saturated experts: that is a property of capacity MoE, not a bug (see
    tests/test_models.py::test_moe_drop_vs_nodrop).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # GROUPED dispatch: tokens route in groups of <= moe_chunk per batch
    # row, with per-group capacity.  Two effects (both measured in §Perf):
    # - dispatch temporaries carry the batch dim and stay DP-sharded under
    #   pjit (a global flat dispatch replicates the (T*k, d) gather per
    #   model-rank: 425 GiB/device on olmoe train_4k);
    # - groups are scanned with per-group remat, so the (group*k, d)
    #   gather/scatter spine (8x token volume for top-8) is a transient,
    #   not a layer-lifetime buffer (29 GiB/device -> per-chunk).
    group = s if not cfg.moe_chunk else min(cfg.moe_chunk, s)
    if s % group != 0:
        group = s  # fall back to one group per row
    n_groups = s // group
    cap = max(8, -(-group * k // 8) * 8) if no_drop \
        else capacity(cfg, group)
    cap = min(cap, group * k)

    # -- routing (all rows at once; f32) -------------------------------------
    logits = jnp.einsum(
        "bsd,de->bse", x, params["router"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)            # (B, S, E) f32
    top_p, top_ids = jax.lax.top_k(probs, k)           # (B, S, k)
    top_p = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9
    )  # renormalize over chosen experts (OLMoE/Mixtral convention)

    # -- aux load-balance loss (Switch eq. 4, over top-1 fraction) ----------
    me = jnp.mean(probs, axis=(0, 1))                        # mean router prob
    ce = jnp.mean(
        jax.nn.one_hot(top_ids[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )                                                        # top-1 load
    aux = e * jnp.sum(me * ce)

    def dispatch_row(xt, ids, w):
        """xt: (group, d); ids/w: (group, k) -> (buf (E,cap,d), routing)."""
        flat_e = ids.reshape(-1)                      # (group*k,)
        flat_w = w.reshape(-1).astype(xt.dtype)
        flat_t = jnp.repeat(jnp.arange(group), k)
        order = jnp.argsort(flat_e, stable=True)      # group by expert
        se, stok, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(se, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(group * k) - starts[se]
        keep = pos < cap
        dest = jnp.where(keep, se * cap + pos, e * cap)  # overflow row
        buf = jnp.zeros((e * cap + 1, d), xt.dtype)
        buf = buf.at[dest].set(xt[stok] * keep[:, None].astype(xt.dtype))
        return buf[: e * cap].reshape(e, cap, d), dest, stok, sw, keep

    def combine_row(y_row, dest, stok, sw, keep):
        y_flat = jnp.concatenate(
            [y_row.reshape(e * cap, d), jnp.zeros((1, d), y_row.dtype)], 0
        )
        contrib = y_flat[dest] * (sw * keep.astype(y_row.dtype))[:, None]
        return jnp.zeros((group, d), y_row.dtype).at[stok].add(contrib)

    def group_fn(x_g, ids_g, w_g):
        """One dispatch group across the whole batch: (B, group, d) -> same."""
        buf, dest, stok, sw, keep = jax.vmap(dispatch_row)(x_g, ids_g, w_g)
        buf = lshard(buf, "batch", "expert", "expert_capacity", "embed")
        # expert FFN (batched over experts; EP-sharded einsum)
        if cfg.act == "swiglu":
            g_ = jnp.einsum("becd,edf->becf", buf,
                            params["w_gate"].astype(x.dtype))
            u = jnp.einsum("becd,edf->becf", buf,
                           params["w_up"].astype(x.dtype))
            h = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u
        else:
            u = jnp.einsum("becd,edf->becf", buf,
                           params["w_up"].astype(x.dtype))
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
        h = lshard(h, "batch", "expert", "expert_capacity", "ff")
        y = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(x.dtype))
        y = lshard(y, "batch", "expert", "expert_capacity", "embed")
        return jax.vmap(combine_row)(y, dest, stok, sw, keep)

    if n_groups == 1:
        out = group_fn(x, top_ids, top_p)
    else:
        xg = x.reshape(b, n_groups, group, d).transpose(1, 0, 2, 3)
        ig = top_ids.reshape(b, n_groups, group, k).transpose(1, 0, 2, 3)
        wg = top_p.reshape(b, n_groups, group, k).transpose(1, 0, 2, 3)

        @jax.checkpoint
        def body(carry, inp):
            return carry, group_fn(*inp)

        _, outs = jax.lax.scan(body, jnp.float32(0.0), (xg, ig, wg))
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)

    out = lshard(out, "batch", "seq_sp", "embed")
    return out, aux.astype(jnp.float32)
