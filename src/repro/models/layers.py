"""Model primitives: norms, RoPE, GQA attention, MLPs.

All functions are pure; parameters are plain dict pytrees declared via
models.declare so init/sharding/dry-run stay consistent.  Activations are
annotated with logical axes through parallel.sharding.lshard (no-op on a
single device).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.declare import DeclTree, ParamDecl
from repro.parallel.sharding import lshard

# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def norm_decls(cfg: ModelConfig) -> DeclTree:
    if cfg.norm == "nonparam_ln":
        return {}  # OLMo: non-parametric LayerNorm — no learned scale/bias
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDecl((cfg.d_model,), ("embed",), "ones"),
            "bias": ParamDecl((cfg.d_model,), ("embed",), "zeros"),
        }
    return {"scale": ParamDecl((cfg.d_model,), ("embed",), "ones")}


def apply_norm(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6)
        out = out * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + 1e-6)
        if cfg.norm == "layernorm":
            out = out * params["scale"].astype(jnp.float32) + params[
                "bias"
            ].astype(jnp.float32)
        # nonparam_ln: no affine (OLMo, arXiv:2402.00838)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions: (..., d_head/2)."""
    half = cfg.d_head // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional query chunking)
# ---------------------------------------------------------------------------


def attention_decls(cfg: ModelConfig) -> DeclTree:
    d, hd = cfg.d_model, cfg.d_head
    h, kv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    return {
        "wq": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDecl((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDecl((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDecl((h, hd, d), ("heads", "head_dim", "embed")),
    }


def _head_mask(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Zero the padded heads' contribution (exact published semantics)."""
    if cfg.n_heads_padded == cfg.n_heads:
        return x
    mask = jnp.arange(cfg.n_heads_padded) < cfg.n_heads
    return x * mask[None, None, :, None].astype(x.dtype)


def _qkv(params: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    cos, sin = rope_freqs(cfg, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", "seq", "kv_heads", "head_dim")
    v = lshard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head H/KV times.

    Flat-head layout keeps the score einsum sharded purely on the head axis
    (no grouped reshape of a sharded dim, which GSPMD can only fix with an
    all-gather + dynamic-slice).  When KV heads are replicated (kv < TP),
    the repeat is a local broadcast.
    """
    b, s, kvh, dh = k.shape
    if kvh == n_heads:
        return k
    group = n_heads // kvh
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, group, dh))
    return k.reshape(b, s, n_heads, dh)


def _sdpa(q, k, v, cfg: ModelConfig, *, causal_offset: int = 0):
    """Scaled-dot-product attention, causal, GQA via repeat-KV.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D).  Queries at absolute position
    causal_offset + i attend to keys at positions <= that.
    """
    b, sq, h, dh = q.shape
    kf = _repeat_kv(k, h)
    vf = _repeat_kv(v, h)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q, kf, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    qpos = jnp.arange(sq) + causal_offset
    kpos = jnp.arange(sk := kf.shape[1])
    mask = kpos[None, :] <= qpos[:, None]  # (Sq, Sk)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vf)
    return out


def _sdpa_chunked(q, k, v, cfg: ModelConfig, chunk: int):
    """Query-chunked attention: scan over query blocks so the live score
    buffer is (B, H, chunk, Sk) instead of (B, H, Sq, Sk).  Memory-term
    lever for the 32k prefill cells (see EXPERIMENTS.md §Perf)."""
    b, sq, h, dh = q.shape
    assert sq % chunk == 0, (sq, chunk)
    nchunk = sq // chunk
    qs = q.reshape(b, nchunk, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def body(i, _):
        out = _sdpa(qs[i], k, v, cfg, causal_offset=i * chunk)
        return out

    outs = jax.lax.map(lambda i: body(i, None), jnp.arange(nchunk))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def attention(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
) -> jax.Array:
    """Full-sequence (training/prefill) attention."""
    q, k, v = _qkv(params, x, cfg, positions)
    if cfg.attn_chunk and x.shape[1] > cfg.attn_chunk:
        out = _sdpa_chunked(q, k, v, cfg, cfg.attn_chunk)
    else:
        out = _sdpa(q, k, v, cfg)
    out = _head_mask(cfg, out)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    # seq_sp: Megatron sequence parallelism — the residual stream between
    # sub-layers is sharded over 'model' (rules_for enables it for
    # train/prefill); GSPMD turns the wo partial-sum all-reduce into a
    # reduce-scatter and the next qkv into an all-gather.
    return lshard(y, "batch", "seq_sp", "embed")


def attention_decode(
    params: Dict,
    x: jax.Array,            # (B, 1, d)
    cfg: ModelConfig,
    k_cache: jax.Array,      # (B, S, KV, D)
    v_cache: jax.Array,
    pos: jax.Array,          # () current position
):
    """One-token decode against a KV cache; returns (y, k_cache, v_cache)."""
    positions = jnp.full((x.shape[1],), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1
    )
    k_cache = lshard(k_cache, "batch", "seq_kv", "kv_heads", "head_dim")
    v_cache = lshard(v_cache, "batch", "seq_kv", "kv_heads", "head_dim")

    b, sq, h, dh = q.shape
    kf = _repeat_kv(k_cache, h)
    vf = _repeat_kv(v_cache, h)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q, kf, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    kpos = jnp.arange(kf.shape[1])
    mask = kpos[None, :] <= pos  # attend to everything written so far
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vf)
    out = _head_mask(cfg, out)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_decls(cfg: ModelConfig, d_ff: Optional[int] = None) -> DeclTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamDecl((d, f), ("embed", "ff")),
            "w_up": ParamDecl((d, f), ("embed", "ff")),
            "w_down": ParamDecl((f, d), ("ff", "embed")),
        }
    return {
        "w_up": ParamDecl((d, f), ("embed", "ff")),
        "w_down": ParamDecl((f, d), ("ff", "embed")),
    }


def mlp(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    h = lshard(h, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    return lshard(y, "batch", "seq_sp", "embed")
