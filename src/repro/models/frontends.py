"""Modality frontend STUBS for the [vlm]/[audio] backbones.

Per the assignment, the transformer BACKBONE is what's specified; the
modality frontend supplies *precomputed* patch/frame embeddings through
``input_specs()``:

- internvl2-26b [vlm]: the real frontend is InternViT-6B producing patch
  embeddings projected to d_model; here a (batch, prefix_len, d_model)
  embedding tensor arrives as an input (prefix_len=256 patches/image).
- musicgen-medium [audio]: the real frontend is EnCodec; the backbone is a
  decoder over EnCodec tokens (vocab 2048) with a conditioning prefix of
  (batch, prefix_len, d_model) frame embeddings (prefix_len=64).

The prefix embeddings are concatenated ahead of the token embeddings; loss
and decode operate on token positions only (see models.lm).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def prefix_embed_shape(
    cfg: ModelConfig, batch: int
) -> Optional[Tuple[int, int, int]]:
    if cfg.frontend == "none" or cfg.prefix_len == 0:
        return None
    return (batch, cfg.prefix_len, cfg.d_model)


def synthetic_prefix(key: jax.Array, cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Optional[jax.Array]:
    shape = prefix_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.02
