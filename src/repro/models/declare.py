"""Declarative parameters: one declaration drives init AND sharding.

Each parameter is declared once with (shape, logical axes, init).  From the
same tree of declarations we derive:
- initialized arrays (models.lm.init_params),
- logical-axes trees -> PartitionSpecs for any mesh (parallel.sharding),
- abstract ShapeDtypeStructs for the dry-run (no allocation).

This is the single-source-of-truth property that keeps the dry-run, the
smoke tests and elastic restore consistent by construction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "fan_in"              # fan_in | normal | zeros | ones | custom
    scale: float = 1.0
    custom: Any = None                # callable(key, shape, dtype)
    dtype: Optional[str] = None       # override model dtype (e.g. "float32"
                                      # for numerically sensitive params)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def resolve_dtype(self, model_dtype):
        import numpy as np  # noqa: PLC0415

        return np.dtype(self.dtype) if self.dtype else model_dtype


DeclTree = Dict[str, Any]  # nested dicts of ParamDecl


def init_tree(key: jax.Array, decls: DeclTree, dtype) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten(
        decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )
    keys = jax.random.split(key, len(flat))
    leaves = [_init_one(k, d, dtype) for k, d in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _init_one(key: jax.Array, d: ParamDecl, dtype) -> jax.Array:
    dtype = d.resolve_dtype(dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "custom":
        return d.custom(key, d.shape, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32)
                * d.scale).astype(dtype)
    if d.init == "fan_in":
        fan_in = d.shape[0] if len(d.shape) == 1 else math.prod(d.shape[:-1])
        # stacked layer params: leading "layers" axis is not fan-in
        if d.axes and d.axes[0] == "layers" and len(d.shape) > 1:
            fan_in = math.prod(d.shape[1:-1]) or d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def axes_tree(decls: DeclTree) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda d: d.axes, decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def abstract_tree(decls: DeclTree, dtype) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.resolve_dtype(dtype)),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def stack_layers(decl: ParamDecl, n: int) -> ParamDecl:
    """Prepend the scan ('layers') axis to a declaration."""
    return dataclasses.replace(
        decl, shape=(n, *decl.shape), axes=("layers", *decl.axes)
    )
