"""HLO text analysis: collective inventory for the roofline's third term.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
accounting, so we parse the optimized HLO: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute instruction, its result
bytes, and its participant-group size, then convert to per-device wire bytes
with the standard ring-algorithm factors.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,128]{2,1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _tuple_bytes(inner: str) -> int:
    # tuple result: "(f32[128]{0}, f32[128]{0})"
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", inner):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[num_groups, group_size]<=[...]
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1).strip()
        if first:
            return len(first.split(","))
    if _SRC_TGT_RE.search(line):
        return 2  # permute: pairwise
    return total_devices


def wire_bytes(op: str, result_bytes: int, group: int) -> float:
    """Per-device bytes on the wire, ring-algorithm convention."""
    g = max(group, 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)   # input = result * g
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


def analyze_collectives(hlo_text: str, total_devices: int) -> Dict:
    """Returns {'ops': [...], 'per_op': {op: {count, result_bytes,
    wire_bytes}}, 'total_wire_bytes': float}."""
    per_op: Dict[str, Dict] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
    )
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        # async pairs: count the -start, skip the -done
        if "-done(" in line:
            continue
        tuple_inner, dtype, dims, op = m.groups()
        if tuple_inner is not None:
            rb = _tuple_bytes(tuple_inner)
        else:
            rb = _shape_bytes(dtype, dims)
        g = _group_size(line, total_devices)
        w = wire_bytes(op, rb, g)
        ent = per_op[op]
        ent["count"] += 1
        ent["result_bytes"] += rb
        ent["wire_bytes"] += w
    total = sum(e["wire_bytes"] for e in per_op.values())
    return {
        "per_op": dict(per_op),
        "total_wire_bytes": total,
    }
