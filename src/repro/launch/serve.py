"""Batched serving driver: prefill + decode with the same job machinery.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.cancellation import CancellationToken
from repro.models import lm
from repro.runtime import backend as backend_mod


def serve_batch(
    *,
    arch: str,
    smoke: bool,
    batch: int,
    prompt_len: int,
    gen: int,
    temperature: float = 0.0,
    token: CancellationToken | None = None,
    seed: int = 0,
):
    backend_mod.load()
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg)
    max_seq = prompt_len + gen

    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (batch, prompt_len), 0, cfg.vocab
    )

    t0 = time.time()
    prefill = jax.jit(
        lambda p, t: lm.prefill_step(p, t, cfg, max_seq=max_seq)
    )
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
    t0 = time.time()
    for i in range(gen):
        if token is not None and token.cancelled():
            break
        out_tokens.append(tok)
        logits, cache = step(params, cache, tok.astype(jnp.int32),
                             jnp.int32(prompt_len + i))
        if temperature > 0:
            k = jax.random.fold_in(key, 100 + i)
            tok = jax.random.categorical(
                k, logits[:, -1, :cfg.vocab] / temperature
            )[:, None]
        else:
            tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    generated = jnp.concatenate(out_tokens, axis=1) if out_tokens else None
    return {
        "generated": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * len(out_tokens) / max(t_decode, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = serve_batch(
        arch=args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
        temperature=args.temperature,
    )
    print(f"prefill {out['prefill_s']:.2f}s; decode {out['decode_s']:.2f}s "
          f"({out['tokens_per_s']:.1f} tok/s)")
    print("sample:", np.asarray(out["generated"][0])[:16])


if __name__ == "__main__":
    main()
