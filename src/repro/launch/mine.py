"""The paper's data mining app, as a launcher: DBSCAN/K-Means jobs with
cancellation, persistence and progress readout.

    PYTHONPATH=src python -m repro.launch.mine --algo dbscan \
        --features 2 --clusters 6 --size 1024 --workdir /tmp/mine
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.core import dbscan, kmeans
from repro.core.cancellation import CancellationToken
from repro.core.jobs import JobState, JobStore
from repro.data.synthetic import ClusterSpec, make_blobs
from repro.runtime import backend as backend_mod
from repro.runtime.preemption import HoldAlive, PreemptionGuard


def run_mining_job(
    *,
    algo: str,
    features: int,
    clusters: int,
    size: int,
    workdir: str,
    use_kernel: bool = True,
    seed: int = 0,
    token: CancellationToken | None = None,
) -> dict:
    backend_mod.load()
    jobs = JobStore(os.path.join(workdir, "jobs.db"))
    jobs.recover_orphans()
    jid = jobs.enqueue("mine", {
        "algo": algo, "features": features, "clusters": clusters,
        "size": size,
    })
    job = jobs.claim_next(kind="mine")
    assert job is not None

    spec = ClusterSpec(features, clusters, size)
    key = jax.random.PRNGKey(seed)
    x, _, _ = make_blobs(key, spec)
    token = token or CancellationToken()

    t0 = time.time()
    result: dict = {"job_id": job.job_id, "algo": algo}
    with PreemptionGuard(token), HoldAlive(jobs, job.job_id):
        if algo == "dbscan":
            cfg = dbscan.DBSCANConfig.paper_defaults(features)
            cfg = dbscan.DBSCANConfig(
                eps=cfg.eps, min_pts=cfg.min_pts, use_kernel=use_kernel
            )
            res = dbscan.fit_cancellable(
                x, cfg, token=token,
                on_progress=lambda cid, nexp: jobs.report_progress(
                    job.job_id, clusters_found=cid, expansions=nexp
                ),
            )
            result.update(
                n_clusters=int(res.n_clusters),
                noise=int(np.sum(np.asarray(res.labels) == 0)),
                cancelled=res.cancelled,
            )
        elif algo == "kmeans":
            cfg = kmeans.KMeansConfig(k=clusters, use_kernel=use_kernel)
            res = kmeans.fit_cancellable(
                key, x, cfg, token=token,
                on_progress=lambda it, shift: jobs.report_progress(
                    job.job_id, step=it, shift=shift
                ),
            )
            result.update(
                iterations=int(res.iterations),
                inertia=float(res.inertia),
                converged=bool(res.converged),
                cancelled=res.cancelled,
            )
        else:
            raise ValueError(f"unknown algo {algo!r}")

        final = JobState.SUSPENDED if result.get("cancelled") \
            else JobState.SUCCEEDED
        jobs.transition(job.job_id, final)
    result["wall_s"] = time.time() - t0
    result["final_state"] = final.value
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", choices=("dbscan", "kmeans"), required=True)
    ap.add_argument("--features", type=int, default=2)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--size", type=int, default=1024)
    ap.add_argument("--workdir", default="/tmp/repro_mine")
    ap.add_argument("--no-kernel", action="store_true")
    args = ap.parse_args()
    out = run_mining_job(
        algo=args.algo, features=args.features, clusters=args.clusters,
        size=args.size, workdir=args.workdir, use_kernel=not args.no_kernel,
    )
    print(out)


if __name__ == "__main__":
    main()
