"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the wrapper-library discipline of
repro.runtime.backend — device count is locked at first query, and
dryrun.py needs to set XLA_FLAGS before that happens).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) (data, model).  Two pods: (2, 16, 16)
    (pod, data, model) — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh for subprocess tests (8 host devices)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1D data mesh (CPU smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
