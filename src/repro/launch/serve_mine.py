"""Clustering-as-a-service launcher: drive the batched mining service.

Generates a synthetic multi-tenant workload (the paper's dataset grid as
request traffic), submits it at an offered rate through the async
:class:`~repro.service.MiningClient`, and prints the serving scorecard —
p50/p99 latency, batch occupancy, per-lane busy time, cache hits, and the
modeled energy spend per paradigm.  Backpressure is honoured: when
admission sheds load with ``BacklogFull``, the driver sleeps the rejected
request's ``retry_after`` estimate and resubmits instead of hammering the
door.  ``--resume`` first completes any batches a previous (killed)
process left SUSPENDED; ``--recover`` additionally replays every
admitted-but-unbatched request from the write-ahead admission log, so a
``kill -9`` at any moment loses nothing that was admitted.  ``--oversized N`` mixes in N requests larger than
the per-device memory budget (``--device-budget-mb``): the cost model
routes them to the ``distributed`` lane, which shards each across every
local device.  ``--bucket-policy`` picks how batch shapes are padded
(``pow2`` / ``linear[:STEP]`` / ``adaptive``, the self-tuning default —
see ``docs/bucketing_study.md``).

    PYTHONPATH=src python -m repro.launch.serve_mine --workdir /tmp/svc \
        --requests 32 --tenants 4 --rate 100 --algo mixed --executor auto

    # oversized mix on a 4-device CPU mesh
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve_mine --workdir /tmp/svc \
        --requests 16 --oversized 2 --device-budget-mb 0.25
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import dbscan
from repro.data.synthetic import ClusterSpec, make_blobs
from repro.runtime import backend as backend_mod
from repro.runtime.preemption import PreemptionGuard
from repro.service import (
    BacklogFull,
    ClusteringService,
    EnergyBudgetExceeded,
    JobSuspended,
    MiningClient,
    TelemetryServer,
    chrome_trace,
)

MAX_RESUBMITS = 3
# An energy-budget rejection whose refill takes longer than this is shed
# immediately — a load generator shouldn't stall the offered rate waiting
# for one tenant's joule bucket.
MAX_ENERGY_WAIT_S = 2.0


def build_workload(n_requests: int, tenants: int, algo: str, *,
                   features: int = 2, clusters: int = 4,
                   points: int = 64, seed: int = 0,
                   oversized: int = 0, oversized_points: int = 1024):
    """(tenant, algo, data, params) tuples from the paper's generator.

    ``oversized`` appends that many extra-large K-Means requests
    (``oversized_points`` points per cluster) to the mix — with a small
    ``--device-budget-mb`` these exceed the per-device budget and exercise
    the distributed lane under real traffic.
    """
    cfg = dbscan.DBSCANConfig.paper_defaults(features)
    out = []
    for i in range(n_requests):
        this_algo = algo if algo != "mixed" else ("dbscan", "kmeans")[i % 2]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        x, _, _ = make_blobs(key, ClusterSpec(features, clusters, points))
        params = (
            {"eps": cfg.eps, "min_pts": cfg.min_pts}
            if this_algo == "dbscan"
            else {"k": clusters, "seed": i, "max_iters": 50}
        )
        out.append((f"tenant-{i % tenants}", this_algo, np.asarray(x), params))
    for j in range(oversized):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), j)
        x, _, _ = make_blobs(
            key, ClusterSpec(features, clusters, oversized_points))
        out.append((f"tenant-{j % tenants}", "kmeans", np.asarray(x),
                    {"k": clusters, "seed": 10_000 + j, "max_iters": 50}))
    return out


def submit_with_backoff(client: MiningClient, tenant, algo, data, *,
                        params, executor=None, ttl=None):
    """Submit one request, honouring BacklogFull.retry_after on rejection."""
    for attempt in range(MAX_RESUBMITS):
        try:
            return client.submit(tenant, algo, data, params=params,
                                 executor=executor, ttl=ttl)
        except BacklogFull as e:
            if attempt + 1 == MAX_RESUBMITS:
                break              # shedding anyway; don't sleep for it
            time.sleep(e.retry_after)
        except EnergyBudgetExceeded as e:
            if e.retry_after > MAX_ENERGY_WAIT_S or attempt + 1 == MAX_RESUBMITS:
                break              # joule refill too slow — shed the request
            time.sleep(e.retry_after)
    return None   # shed after MAX_RESUBMITS rejects


def drive(client: MiningClient, workload, rate: float,
          executor: str | None, timeout: float = 300.0,
          ttl: float | None = None) -> dict:
    """Submit at the offered rate; wait for every handle; count failures."""
    handles = []
    gap = 1.0 / rate if rate > 0 else 0.0
    failures = {"suspended": 0, "dropped": 0, "rejected": 0}
    t0 = time.time()
    for i, (tenant, algo, data, params) in enumerate(workload):
        target = t0 + i * gap
        delay = target - time.time()
        if delay > 0:
            time.sleep(delay)
        h = submit_with_backoff(client, tenant, algo, data, params=params,
                                executor=executor, ttl=ttl)
        if h is None:
            failures["rejected"] += 1
        else:
            handles.append(h)
    for h in handles:
        try:
            h.result(timeout)
        except JobSuspended:
            failures["suspended"] += 1
        except Exception:            # RequestDropped, deadline expiry, ...
            failures["dropped"] += 1
    return failures


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface (separate so the docs gate can introspect it)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_serve_mine")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="offered load, requests/s")
    ap.add_argument("--algo", choices=("dbscan", "kmeans", "mixed"),
                    default="mixed")
    ap.add_argument("--executor",
                    choices=("auto", "pallas-kernel", "jax-ref", "numpy-mt",
                             "distributed"),
                    default="auto")
    ap.add_argument("--features", type=int, default=2)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--points", type=int, default=64,
                    help="points per cluster per request")
    ap.add_argument("--oversized", type=int, default=0,
                    help="extra oversized K-Means requests mixed into the "
                         "load (they bypass coalescing and ride the "
                         "distributed lane when over the device budget)")
    ap.add_argument("--oversized-points", type=int, default=1024,
                    help="points per cluster for each oversized request")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="per-device memory budget; requests whose working "
                         "set exceeds it are sharded across all devices "
                         "(default: fraction of the discovered chip's HBM)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--no-continuous", action="store_true",
                    help="disable continuous batching (on by default): "
                         "batches then run to completion before queued "
                         "requests dispatch, instead of compatible "
                         "requests joining in-flight batches at iteration "
                         "boundaries and finished items retiring early")
    ap.add_argument("--join-window", type=float, default=None,
                    help="seconds after a continuous batch starts during "
                         "which queued compatible requests may join it "
                         "(default: open for the batch's whole lifetime)")
    ap.add_argument("--warm-start", default=None,
                    help="pre-compile executables at startup from a JSON "
                         "list of shape specs, e.g. "
                         "'[{\"algo\": \"kmeans\", \"features\": 2, "
                         "\"n\": 1024, \"k\": 4}]' — first requests then "
                         "hit the executable cache instead of paying "
                         "XLA compilation")
    ap.add_argument("--bucket-policy", default="adaptive",
                    help="batch-shape bucket policy: 'pow2', "
                         "'linear[:STEP]', or 'adaptive[:MAX_BUCKETS"
                         "[:REFIT_EVERY]]' (default: adaptive — behaves "
                         "like pow2 until fitted; see "
                         "docs/bucketing_study.md)")
    ap.add_argument("--ttl", type=float, default=None,
                    help="per-request deadline, seconds from submit")
    ap.add_argument("--power-cap", type=float, default=None,
                    help="service-wide dispatch power cap, watts: lanes "
                         "acquire each batch's predicted joules from a "
                         "token bucket refilled at this rate, so modeled "
                         "draw stays at or under the cap (latency is "
                         "traded for energy; see docs/energy_study.md)")
    ap.add_argument("--joule-rate", type=float, default=None,
                    help="per-tenant joule budget refill rate, J/s: "
                         "admission prices each request with the device-"
                         "class cost model and rejects over-budget "
                         "tenants with EnergyBudgetExceeded + retry_after")
    ap.add_argument("--joule-burst", type=float, default=50.0,
                    help="per-tenant joule budget bucket depth, joules "
                         "(only meaningful with --joule-rate)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text on this port for the run "
                         "(GET /metrics; also /snapshot, /trace, /healthz; "
                         "0 binds an ephemeral port and prints it)")
    ap.add_argument("--trace-dump", default=None,
                    help="write every recorded span as Chrome trace-event "
                         "JSON to this path at exit (open in Perfetto or "
                         "chrome://tracing)")
    ap.add_argument("--resume", action="store_true",
                    help="complete SUSPENDED batches from a previous run")
    ap.add_argument("--recover", action="store_true",
                    help="full restart path: resume SUSPENDED batches AND "
                         "replay admitted-but-unbatched requests from the "
                         "write-ahead admission log (admitted means "
                         "durable; implies --resume)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N worker processes behind the consistent-"
                         "hash FleetRouter instead of one in-process "
                         "service (each worker gets its own workdir + WAL "
                         "under --workdir; 0 = single-process mode)")
    ap.add_argument("--router-port", type=int, default=None,
                    help="with --fleet: serve the fleet-level Prometheus "
                         "exposition (repro_fleet_* with a worker label; "
                         "also /snapshot and cross-worker /trace) on this "
                         "port; 0 binds an ephemeral port and prints it")
    ap.add_argument("--standby", default=None, metavar="HOST:PORT",
                    help="ship the write-ahead admission log to a warm "
                         "StandbyReplica listening at this address for the "
                         "whole run, so a lost workdir can be promoted "
                         "without losing an admitted request "
                         "(single-process mode; see the zero-downtime "
                         "chapter in docs/OPERATIONS.md)")
    ap.add_argument("--reload", default=None, metavar="JSON",
                    help="apply a live config reload before driving load: "
                         "a JSON object of reloadable knobs, e.g. "
                         "'{\"tenant_rate\": 50}' — fanned to every "
                         "worker's POST /reload with --fleet, applied "
                         "in-process otherwise; the bumped config epoch "
                         "is printed and stamped into traces and metrics")
    ap.add_argument("--rolling-restart", action="store_true",
                    help="with --fleet: after the workload drains, restart "
                         "every worker one at a time (drain, respawn over "
                         "the same workdir, re-pin the router) and drive a "
                         "verification batch — the zero-downtime upgrade "
                         "path")
    return ap


def run_fleet(args) -> None:
    """--fleet N: the same workload through N worker processes behind the
    consistent-hash router, then the fleet scorecard."""
    from repro.service.fleet import FleetRouter, WorkerManager

    worker_config = {
        "max_batch": args.max_batch,
        "max_wait_s": args.max_wait_ms / 1000.0,
        "continuous": not args.no_continuous,
        "join_window_s": args.join_window,
        "bucket_policy": args.bucket_policy,
    }
    if args.power_cap is not None:
        worker_config["power_cap_watts"] = args.power_cap
    if args.joule_rate is not None:
        worker_config["tenant_joule_rate"] = args.joule_rate
        worker_config["tenant_joule_burst"] = args.joule_burst
    if args.warm_start is not None:
        worker_config["warm_start"] = json.loads(args.warm_start)
    if args.device_budget_mb is not None:
        worker_config["device_budget_bytes"] = args.device_budget_mb * 2**20
    manager = WorkerManager(args.workdir, args.fleet,
                            worker_config=worker_config)
    manager.start()
    router = FleetRouter(manager)
    exporter = None
    try:
        if args.router_port is not None:
            exporter = router.serve_metrics(args.router_port)
            print(f"# fleet telemetry: "
                  f"http://127.0.0.1:{exporter.port}/metrics")
        if args.reload:
            changes = json.loads(args.reload)
            result = router.reload(changes)
            print(f"# reload: epochs {result['epochs']}, "
                  f"converged {result['converged']}, "
                  f"errors {result['errors']}")
        workload = build_workload(
            args.requests, args.tenants, args.algo,
            features=args.features, clusters=args.clusters,
            points=args.points, oversized=args.oversized,
            oversized_points=args.oversized_points)
        executor = None if args.executor == "auto" else args.executor
        failures = drive(router, workload, args.rate, executor,
                         ttl=args.ttl)
        if args.rolling_restart:
            manager.rolling_restart()
            for r in manager.restarts:
                print(f"# rolling restart: {r['worker']} "
                      f"pid {r['old_pid']} -> {r['new_pid']} "
                      f"in {r['duration_s']:.2f}s")
            # the upgraded fleet must still serve
            verify = build_workload(min(args.requests, 8), args.tenants,
                                    args.algo, features=args.features,
                                    clusters=args.clusters,
                                    points=args.points, seed=1)
            post = drive(router, verify, args.rate, executor, ttl=args.ttl)
            print(f"# rolling restart: post-restart batch failures {post}")
        snap = router.metrics_snapshot()
        fleet = snap["fleet"]
        print(json.dumps(fleet, indent=2, default=str))
        per_worker = {
            name: (ws.get("totals") or {}).get("requests", 0)
            for name, ws in snap["workers"].items()}
        print(f"# fleet: {fleet['alive']}/{fleet['n_workers']} workers "
              f"alive, requests per worker {per_worker}, "
              f"router {fleet['router']['submitted']} submitted / "
              f"{fleet['router']['retries']} retries / "
              f"{fleet['router']['spills']} bounded-load spills, "
              f"failures {failures}")
    finally:
        if exporter is not None:
            exporter.stop()
        router.close()
        manager.stop()


def main() -> None:
    parser = build_parser()
    args = parser.parse_args()
    if args.standby and args.fleet:
        parser.error("--standby is single-process mode only: each fleet "
                     "worker needs its own standby (see "
                     "WorkerManager(standbys=...))")
    if args.rolling_restart and not args.fleet:
        parser.error("--rolling-restart needs --fleet N (the in-process "
                     "equivalent is ClusteringService.handover())")
    if args.fleet:
        run_fleet(args)
        return

    backend_mod.load()
    warm_start = (json.loads(args.warm_start)
                  if args.warm_start is not None else None)
    service = ClusteringService(
        args.workdir,
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1000.0,
        continuous=not args.no_continuous,
        join_window_s=args.join_window,
        warm_start=warm_start,
        bucket_policy=args.bucket_policy,
        device_budget_bytes=(None if args.device_budget_mb is None
                             else args.device_budget_mb * 2**20),
        power_cap_watts=args.power_cap,
        tenant_joule_rate=args.joule_rate,
        tenant_joule_burst=args.joule_burst,
    )
    client = MiningClient(service=service)
    shipper = None
    if args.standby:
        from repro.service.replicate import WalShipper

        s_host, _, s_port = args.standby.rpartition(":")
        shipper = WalShipper(service.wal, s_host or "127.0.0.1",
                             int(s_port)).start()
        service.attach_replicator(shipper)
        print(f"# replicating WAL to standby {args.standby}")
    exporter = None
    if args.metrics_port is not None:
        exporter = TelemetryServer(service.metrics_snapshot,
                                   tracer=service.tracer,
                                   port=args.metrics_port).start()
        print(f"# telemetry: http://127.0.0.1:{exporter.port}/metrics")
    if args.resume and not args.recover:
        outcomes = client.resume_suspended()
        for o in outcomes:
            print(f"resumed job {o.job_id}: {o.algo} x{o.size} "
                  f"on {o.executor} in {o.exec_s:.3f}s")
        if not outcomes:
            print("nothing to resume")

    workload = build_workload(
        args.requests, args.tenants, args.algo,
        features=args.features, clusters=args.clusters, points=args.points,
        oversized=args.oversized, oversized_points=args.oversized_points)
    executor = None if args.executor == "auto" else args.executor
    # SIGTERM/SIGINT -> cooperative preemption: in-flight batches
    # checkpoint and park SUSPENDED (finish later with --resume)
    with PreemptionGuard(service.token), service:
        if args.recover:
            # resume suspended batches, then replay every admitted request
            # the dead process never batched (the WAL's lose-nothing path)
            summary = client.recover()
            for o in summary["outcomes"]:
                print(f"resumed job {o.job_id}: {o.algo} x{o.size} "
                      f"on {o.executor} in {o.exec_s:.3f}s")
            print(f"recovered: {summary['resumed_batches']} suspended "
                  f"batch(es), {summary['replayed']} replayed request(s) "
                  f"({summary['cache_hits']} cache hits, "
                  f"{summary['rejected']} rejected)")
            for h in summary["requests"]:
                try:
                    h.result(300)
                except Exception as e:
                    print(f"replayed request {h.request_id} failed: {e!r}")
        if args.reload:
            cfg = service.apply_config(json.loads(args.reload))
            print(f"# reload: epoch {cfg.epoch} applied")
        failures = drive(client, workload, args.rate, executor, ttl=args.ttl)
    if shipper is not None:
        shipper.stop(final_ship=True)
        st = shipper.stats()
        print(f"# standby: {st['bytes_shipped']} bytes shipped in "
              f"{st['chunks_shipped']} chunks, "
              f"lag {st['standby_lag_entries']} entries, "
              f"{st['ship_errors']} ship errors")
    if exporter is not None:
        exporter.stop()
    if args.trace_dump:
        with open(args.trace_dump, "w") as fh:
            json.dump(chrome_trace(service.export_trace()), fh)
        print(f"# trace dump: {args.trace_dump}")
    snap = client.metrics()
    print(json.dumps(snap, indent=2, default=str))
    lanes = {name: f"{st['busy_s']:.3f}s/{st['batches']}b"
             for name, st in snap["lanes"].items() if st["batches"]}
    bkt = snap["bucketing"]
    print(f"# {snap['requests']} requests, "
          f"p50 {snap['p50_latency_s'] * 1e3:.1f}ms / "
          f"p99 {snap['p99_latency_s'] * 1e3:.1f}ms, "
          f"occupancy {snap['mean_occupancy']:.2f}, "
          f"lanes {lanes}, failures {failures}")
    print(f"# bucketing [{bkt['policy']['name']}]: "
          f"padding waste {bkt['padding_waste']:.2%}, "
          f"{bkt['recompiles']} compiled shape(s)")
    energy = snap.get("energy") or {}
    cap = energy.get("cap") or {}
    by_class = {name: f"{tot.get('modeled_joules', 0.0):.2f}J/"
                      f"{tot.get('batches', 0)}b"
                for name, tot in sorted((energy.get("by_class")
                                         or {}).items())}
    cap_note = (f", cap {energy['power_cap_watts']:g}W "
                f"(throttled {cap.get('throttled_s_total', 0.0):.2f}s "
                f"over {cap.get('throttles', 0)} batch(es))"
                if energy.get("power_cap_watts") is not None else "")
    budget = energy.get("budget") or {}
    budget_note = (f", budget rejections {budget.get('rejections', 0)}"
                   if budget.get("tenant_joule_rate") is not None else "")
    print(f"# energy: {energy.get('joules_total', 0.0):.2f}J total, "
          f"{energy.get('joules_per_point', 0.0) * 1e3:.3f}mJ/point, "
          f"classes {by_class}{cap_note}{budget_note}")
    slo = snap["slo"]
    print(f"# slo: {'OK' if slo['ok'] else 'VIOLATED'} — "
          f"p{slo['latency_percentile']:g} "
          f"{slo['observed_latency_s'] * 1e3:.1f}ms vs "
          f"{slo['latency_target_s'] * 1e3:.0f}ms target "
          f"(burn {slo['latency_burn_rate']:.2f}), "
          f"error rate {slo['observed_error_rate']:.3f} vs "
          f"{slo['error_rate_target']:.3f} "
          f"(burn {slo['errors_burn_rate']:.2f})")


if __name__ == "__main__":
    main()
