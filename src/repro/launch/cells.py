"""Dry-run cell construction: (arch x shape x mesh) -> lowerable function.

Shared by launch/dryrun.py (compile + analyze) and benchmarks/roofline.py
(interpretation).  Everything here is ShapeDtypeStruct-abstract: no array is
ever allocated for the full configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeSpec, cell_applicable, shape_by_name
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.parallel import resolve
from repro.parallel.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_axis_rules,
    spec_for_shape,
)
from repro.train import step as train_step_mod
from repro.train.step import (
    abstract_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    train_batch_shapes,
    train_state_axes,
)


def rules_for(cfg: ModelConfig, shape: ShapeSpec,
              overrides: Optional[Dict[str, Any]] = None,
              tp: int = 16) -> ShardingRules:
    """Per-shape rule adjustments (the deployable policy; §Perf logs how it
    was derived from the naive baseline).

    - train/prefill: Megatron sequence parallelism — the residual stream
      between sub-layers shards over 'model' (seq_sp), dividing layer-
      boundary activation saves by TP;
    - decode, GQA archs (kv_heads % TP != 0): the KV cache shards over the
      *sequence* dim on 'model' (flash-decode style) instead of replicating
      2-8 KV heads per chip;
    - decode, batch < data axis (long_500k batch=1): the sequence dim also
      takes the idle 'data' axis.
    """
    rules = DEFAULT_RULES
    if shape.kind in ("train", "prefill"):
        rules = rules.override(seq_sp="model")
    if shape.kind == "decode":
        kv_shardable = (
            cfg.n_kv_heads_padded and cfg.n_kv_heads_padded % tp == 0
        )
        seq_axes = [] if kv_shardable else ["model"]
        if shape.global_batch < 16:
            seq_axes = ["data"] + seq_axes
            rules = rules.override(batch=("pod",))
        if seq_axes:
            rules = rules.override(seq_kv=tuple(seq_axes))
    if overrides:
        rules = rules.override(**overrides)
    return rules


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Any                   # python callable to jit
    args: Tuple[Any, ...]     # abstract args
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...]
    rules: ShardingRules


def _batch_sharding(mesh: Mesh, rules: ShardingRules, shapes: Dict[str, Any]):
    out = {}
    for name, sds in shapes.items():
        if name == "prefix_embeds":
            axes = ("batch", "seq", "embed")
        else:
            axes = ("batch", "seq")
        spec = spec_for_shape(rules, axes, mesh, tuple(sds.shape))
        out[name] = NamedSharding(mesh, spec)
    return out


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    rule_overrides: Optional[Dict[str, Any]] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
) -> Cell:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = shape_by_name(shape_name)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"inapplicable cell {arch}x{shape_name}: {why}")
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    rule_overrides = dict(rule_overrides or {})
    zero3 = rule_overrides.pop("_zero3", False)
    rules = rules_for(cfg, shape, rule_overrides, tp=tp)

    params_abs = lm.abstract_params(cfg)
    params_axes = lm.param_axes(cfg)
    param_sh = resolve.tree_shardings(params_axes, params_abs, mesh, rules)
    if zero3:
        # ZeRO-3: parameters also shard over the data axes; GSPMD inserts
        # per-layer all-gathers (fwd/bwd) and reduce-scatters the grads.
        # Needed when TP-sharded params alone exceed HBM (jamba 52B: 6.5
        # GiB bf16 params + 6.5 GiB grads on 16 GiB chips — §Perf).
        param_sh = jax.tree_util.tree_map(
            lambda sh, ab: jax.sharding.NamedSharding(
                mesh, resolve.zero1_spec(sh.spec, tuple(ab.shape), mesh)
            ),
            param_sh, params_abs,
        )

    if shape.kind == "train":
        state_abs = abstract_train_state(cfg)
        state_axes = train_state_axes(cfg)
        state_sh = resolve.train_state_shardings(state_axes, state_abs,
                                                 mesh, rules, zero3=zero3)
        batch_abs = train_batch_shapes(cfg, shape.global_batch, shape.seq_len)
        batch_sh = _batch_sharding(mesh, rules, batch_abs)
        fn = make_train_step(cfg, AdamWConfig())
        return Cell(
            arch=arch, shape=shape, fn=fn,
            args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            # explicit out sharding: donated state must alias its input
            # buffers (inferred shardings can silently break aliasing and
            # double the state in temps — §Perf)
            out_shardings=(state_sh, None),
            donate=(0,),
            rules=rules,
        )

    if shape.kind == "prefill":
        batch_abs = train_batch_shapes(cfg, shape.global_batch, shape.seq_len)
        batch_abs.pop("labels")
        batch_sh = _batch_sharding(mesh, rules, batch_abs)
        fn = make_prefill_step(cfg, max_seq=shape.seq_len)
        return Cell(
            arch=arch, shape=shape, fn=fn,
            args=(params_abs, batch_abs),
            in_shardings=(param_sh, batch_sh),
            out_shardings=None,
            donate=(),
            rules=rules,
        )

    # decode
    cache_abs = lm.abstract_decode_cache(cfg, shape.global_batch,
                                         shape.seq_len)
    cache_axes = lm.cache_axes(cfg, shape.global_batch, shape.seq_len)
    cache_sh = resolve.tree_shardings(cache_axes, cache_abs, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = NamedSharding(
        mesh, spec_for_shape(rules, ("batch", "seq"), mesh,
                             tuple(tok_abs.shape))
    )
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    serve = make_serve_step(cfg)
    return Cell(
        arch=arch, shape=shape, fn=serve,
        args=(params_abs, cache_abs, tok_abs, pos_abs),
        in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),  # donated cache must alias
        donate=(1,),
        rules=rules,
    )


def lower_cell(cell: Cell, mesh: Mesh):
    """jit + lower under the mesh context (constraints need it active)."""
    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate,
    )
    with mesh, logical_axis_rules(cell.rules):
        return jitted.lower(*cell.args)
