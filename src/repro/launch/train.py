"""End-to-end training driver: jobs + checkpoints + preemption + watchdog.

This is the paper's app loop at cluster scale.  The lifecycle mirrors
§II.A exactly:

1. attach to the job store; sweep orphans (the activity's reattach);
2. claim a job (new or SUSPENDED); restore its checkpoint if resuming;
3. hold a wake lock (HoldAlive heartbeats) and run steps, polling the
   cancellation token *between* jitted steps;
4. on SIGTERM/cancel: emergency-checkpoint, mark SUSPENDED, exit clean;
5. on completion: final checkpoint, mark SUCCEEDED.

Run small on CPU (smoke config):

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 20 --workdir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.elastic import emergency_save
from repro.checkpoint.store import AsyncCheckpointer, CheckpointStore
from repro.configs import get_config, get_smoke_config
from repro.core.cancellation import CancellationToken, CancelReason
from repro.core.jobs import JobState, JobStore
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import make_schedule
from repro.runtime import backend as backend_mod
from repro.runtime.preemption import HoldAlive, PreemptionGuard
from repro.runtime.watchdog import StepWatchdog
from repro.train.step import (
    TrainState,
    init_train_state,
    make_train_batch,
    make_train_step,
)


def run_training_job(
    *,
    arch: str,
    smoke: bool,
    steps: int,
    batch: int,
    seq: int,
    workdir: str,
    schedule: str = "wsd",
    ckpt_every: int = 10,
    resume_job: bool = True,
    token: CancellationToken | None = None,
) -> dict:
    backend_mod.load()  # wrapper-library discipline: explicit device init
    cfg = get_smoke_config(arch) if smoke else get_config(arch)

    jobs = JobStore(os.path.join(workdir, "jobs.db"))
    orphans = jobs.recover_orphans()
    if orphans:
        print(f"recovered orphaned jobs: {orphans}")

    job = jobs.claim_next(kind="train") if resume_job else None
    if job is None:
        jid = jobs.enqueue("train", {
            "arch": arch, "steps": steps, "batch": batch, "seq": seq,
        })
        job = jobs.claim_next(kind="train")
        assert job is not None and job.job_id == jid
    start_step = job.step
    print(f"job {job.job_id}: starting at step {start_step}/{steps}")

    store = CheckpointStore(os.path.join(workdir, "ckpt"))
    ckpt = AsyncCheckpointer(store)
    token = token or CancellationToken()

    sched = make_schedule(schedule, steps)
    train_step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), sched))

    key = jax.random.PRNGKey(hash(arch) % 2**31)
    state = init_train_state(key, cfg)
    if start_step > 0 and store.latest_step() is not None:
        state = store.restore(store.latest_step(), state)
        print(f"restored checkpoint step {store.latest_step()}")

    wd = StepWatchdog(
        lambda el, med: print(f"straggler: step {el:.2f}s vs median {med:.2f}s"),
        factor=10.0,
    )
    losses = []
    final_state = JobState.SUCCEEDED
    with PreemptionGuard(token), HoldAlive(jobs, job.job_id), wd:
        step = start_step
        while step < steps:
            # the paper's contract: flag polled between kernel executions
            if token.cancelled():
                final_state = JobState.SUSPENDED
                break
            wd.step_begin()
            batch_data = make_train_batch(
                jax.random.fold_in(key, step), cfg, batch, seq
            )
            state, metrics = train_step(state, batch_data)
            wd.step_end()
            step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            jobs.report_progress(job.job_id, step=step, loss=loss)
            if step % ckpt_every == 0 or step == steps:
                ckpt.submit(step, state, metadata={"arch": cfg.name,
                                                   "loss": loss})
                jobs.report_progress(
                    job.job_id,
                    checkpoint_path=os.path.join(store.root, f"step_{step}"),
                )

        ckpt.wait()
        if final_state == JobState.SUSPENDED:
            path = emergency_save(store, step, state, token.reason.value)
            jobs.report_progress(job.job_id, step=step, checkpoint_path=path)
            print(f"suspended at step {step}; emergency checkpoint: {path}")
        jobs.transition(job.job_id, final_state)

    return {
        "job_id": job.job_id,
        "final_state": final_state.value,
        "steps_done": step,
        "losses": losses,
        "stragglers": wd.straggler_events,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--schedule", default="wsd")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()
    out = run_training_job(
        arch=args.arch, smoke=args.smoke, steps=args.steps,
        batch=args.batch, seq=args.seq, workdir=args.workdir,
        schedule=args.schedule, ckpt_every=args.ckpt_every,
    )
    first = out["losses"][0] if out["losses"] else float("nan")
    last = out["losses"][-1] if out["losses"] else float("nan")
    print(f"done: {out['final_state']} steps={out['steps_done']} "
          f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
