import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count at first
# initialization).  Nothing above this line may import jax or repro.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --multi-pod

Per cell it records to results/dryrun/<mesh>/<arch>__<shape>.json:
- memory_analysis (bytes per device: args/outputs/temps/peak),
- cost_analysis (HLO FLOPs, bytes accessed),
- the collective inventory parsed from the optimized HLO,
- wall compile time.

EXPERIMENTS.md §Dry-run / §Roofline are generated from these files by
benchmarks/roofline.py.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_NAMES, get_config  # noqa: E402
from repro.configs.base import SHAPES, cell_applicable, shape_by_name  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.cells import build_cell, lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _compile_once(arch, shape_name, mesh, rule_overrides, cfg_overrides):
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, rule_overrides, cfg_overrides)
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        k: int(getattr(mem, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        )
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis() or {}
    cost_d = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    coll = hlo_mod.analyze_collectives(compiled.as_text(), mesh.size)
    return {
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "collectives": coll,
    }


def _derive_totals(f1: dict, f2: dict, n_groups: int) -> dict:
    """Scan bodies are cost-counted ONCE by XLA (verified in
    EXPERIMENTS.md §Method), so per-cell totals are derived from two
    unrolled shallow compiles: total = f1 + (G-1) * (f2 - f1)."""
    g = n_groups

    def lin(a, b):
        return a + (g - 1) * (b - a)

    out = {
        "flops": lin(f1["cost_analysis"]["flops"],
                     f2["cost_analysis"]["flops"]),
        "bytes_accessed": lin(f1["cost_analysis"]["bytes_accessed"],
                              f2["cost_analysis"]["bytes_accessed"]),
        "transcendentals": lin(f1["cost_analysis"]["transcendentals"],
                               f2["cost_analysis"]["transcendentals"]),
        "wire_bytes": lin(f1["collectives"]["total_wire_bytes"],
                          f2["collectives"]["total_wire_bytes"]),
        "per_op_wire_bytes": {},
    }
    ops = set(f1["collectives"]["per_op"]) | set(f2["collectives"]["per_op"])
    for op in ops:
        a = f1["collectives"]["per_op"].get(op, {}).get("wire_bytes", 0.0)
        b = f2["collectives"]["per_op"].get(op, {}).get("wire_bytes", 0.0)
        out["per_op_wire_bytes"][op] = lin(a, b)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides=None, cfg_overrides=None, tag: str = "",
             analysis: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)

    # Pass A: the deployable program (scan-over-layers) — compile proof +
    # memory analysis + collective schedule.
    full = _compile_once(arch, shape_name, mesh, rule_overrides,
                         cfg_overrides)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "devices": mesh.size,
        "tag": tag,
        "status": "ok",
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "n_groups": cfg.n_groups,
        **full,
    }

    if analysis:
        # Passes B/C: unrolled shallow compiles for exact cost totals
        # (scan bodies are counted once by XLA cost analysis).
        seq = shape_by_name(shape_name).seq_len
        ana = dict(cfg_overrides or {})
        ana.update(scan_layers=False, ssm_chunk=max(seq, 128), attn_chunk=0,
                   loss_chunk=0, moe_chunk=0)
        f1 = _compile_once(arch, shape_name, mesh, rule_overrides,
                           {**ana, "n_layers": cfg.period})
        f2 = _compile_once(arch, shape_name, mesh, rule_overrides,
                           {**ana, "n_layers": 2 * cfg.period})
        result["analysis_depth1"] = f1
        result["analysis_depth2"] = f2
        result["derived"] = _derive_totals(f1, f2, cfg.n_groups)
    return result


def save_result(result: dict, out_dir: str) -> str:
    mesh_dir = os.path.join(out_dir, result["mesh"])
    os.makedirs(mesh_dir, exist_ok=True)
    tag = f"__{result['tag']}" if result.get("tag") else ""
    path = os.path.join(
        mesh_dir, f"{result['arch']}__{result['shape']}{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return path


def iter_cells():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_applicable(cfg, shape)
            yield arch, shape.name, ok, why


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes or args.all:
        meshes = [False, True]

    if args.all:
        cells = [(a, s) for a, s, ok, _ in iter_cells() if ok]
        skips = [(a, s, why) for a, s, ok, why in iter_cells() if not ok]
        for a, s, why in skips:
            print(f"SKIP {a} x {s}: {why}", flush=True)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for multi_pod in meshes:
        mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
        for arch, shape in cells:
            out_path = os.path.join(
                args.out, mesh_name, f"{arch}__{shape}.json"
            )
            if args.skip_existing and os.path.exists(out_path):
                print(f"SKIP(existing) {arch} x {shape} [{mesh_name}]",
                      flush=True)
                continue
            label = f"{arch} x {shape} [{mesh_name}]"
            try:
                # roofline analysis passes only needed on the single pod
                result = run_cell(arch, shape, multi_pod,
                                  analysis=not multi_pod)
                path = save_result(result, args.out)
                flops = result.get("derived", result["cost_analysis"])["flops"]
                print(
                    f"OK   {label}: compile={result['seconds_compile']}s "
                    f"flops={flops:.3e} "
                    f"wire={result['collectives']['total_wire_bytes']:.3e}B "
                    f"-> {os.path.relpath(path)}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((label, repr(e)))
                os.makedirs(os.path.join(args.out, mesh_name), exist_ok=True)
                with open(out_path, "w") as f:
                    json.dump({
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "fail", "error": traceback.format_exc(),
                    }, f, indent=2)
                print(f"FAIL {label}: {e!r}", flush=True)

    print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    if failures:
        for label, err in failures:
            print(f"  FAILED: {label}: {err[:200]}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
