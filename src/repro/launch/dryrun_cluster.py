import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede every other import (see dryrun.py)

"""Dry-run for the paper's OWN technique at pod scale: one distributed
K-Means step (assignment + centroid update) and one DBSCAN frontier
expansion over pod-sharded points.

Shapes (the "pod-scale data mining" cell):
    kmeans_16m:  n = 16,777,216 points, d = 128 features, k = 4096 centroids
    dbscan_1m:   n = 1,048,576 points,  d = 128 (frontier expansion step)

    PYTHONPATH=src python -m repro.launch.dryrun_cluster [--multi-pod] \
        [--strategy pjit|ring] [--dtype float32|bfloat16]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import clustering_step_for_dryrun  # noqa: E402
from repro.core.kmeans import KMeansConfig  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.dryrun import RESULTS_DIR, save_result  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

KMEANS_N = 16 * 1024 * 1024
KMEANS_D = 128
KMEANS_K = 4096
DBSCAN_N = 1024 * 1024


def kmeans_cell(mesh, dtype, tag: str = "", rules_variant: str = "pjit"):
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    x_sh = NamedSharding(mesh, P(daxes, None))
    c_sh = NamedSharding(mesh, P())
    a_sh = NamedSharding(mesh, P(daxes))

    cfg = KMeansConfig(k=KMEANS_K, use_kernel=False)
    step = clustering_step_for_dryrun(cfg)
    x_abs = jax.ShapeDtypeStruct((KMEANS_N, KMEANS_D), dtype)
    c_abs = jax.ShapeDtypeStruct((KMEANS_K, KMEANS_D), jnp.float32)

    jitted = jax.jit(step, in_shardings=(x_sh, c_sh),
                     out_shardings=(a_sh, c_sh, c_sh, c_sh))
    t0 = time.time()
    with mesh:  # lshard constraints need the active mesh
        lowered = jitted.lower(x_abs, c_abs)
        compiled = lowered.compile()
    t = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = hlo_mod.analyze_collectives(compiled.as_text(), mesh.size)
    # no scans inside one step: cost_analysis is exact — mirror it as derived
    cost_d = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    return {
        "arch": "paper-kmeans",
        "shape": "cluster_16m",
        "mesh": ("multi_pod_2x16x16" if "pod" in mesh.axis_names
                 else "single_pod_16x16"),
        "devices": mesh.size,
        "tag": tag,
        "status": "ok",
        "seconds_compile": round(t, 2),
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
            if hasattr(mem, k)
        },
        "cost_analysis": cost_d,
        "collectives": coll,
        "derived": {
            "flops": cost_d["flops"],
            "bytes_accessed": cost_d["bytes_accessed"],
            "transcendentals": cost_d["transcendentals"],
            "wire_bytes": coll["total_wire_bytes"],
            "per_op_wire_bytes": {
                k: v["wire_bytes"] for k, v in coll["per_op"].items()
            },
        },
        "n_params": KMEANS_K * KMEANS_D,
        "n_active_params": KMEANS_K * KMEANS_D,
        "n_groups": 1,
        "problem": {"n": KMEANS_N, "d": KMEANS_D, "k": KMEANS_K,
                    "dtype": str(dtype), "strategy": rules_variant},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        res = kmeans_cell(mesh, dtype, tag=args.tag)
        path = save_result(res, args.out)
        print(f"OK paper-kmeans cluster_16m [{res['mesh']}] "
              f"compile={res['seconds_compile']}s "
              f"flops={res['derived']['flops']:.3e} "
              f"wire={res['derived']['wire_bytes']:.3e} -> {path}")


if __name__ == "__main__":
    main()
