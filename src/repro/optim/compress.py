"""Gradient compression for the DP all-reduce (distributed-optimization trick).

Two codecs, applied per-leaf under shard_map over the data axes so the wire
format is explicit (pjit's implicit psum cannot express quantized reduce):

- int8 uniform quantization with per-leaf scale: psum of int32-accumulated
  int8 payloads (8x wire compression, unbiased with stochastic rounding);
- top-k sparsification with error feedback: only the k largest-|g| entries
  travel; the residual is fed back next step (memory = one grads-sized
  buffer, standard Deep-Gradient-Compression shape).

Compression applies to *data-parallel* reduction only; TP/EP collectives
carry activations and stay full precision.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from repro.runtime.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def int8_encode(g: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_encode(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Keep the top-|g| fraction.  Returns (values, indices, residual)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual


def topk_decode(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), vals.dtype)
    return flat.at[idx].add(vals).reshape(shape)


def compressed_psum_int8(
    mesh: Mesh, grads: Any, key: jax.Array, axes: Tuple[str, ...]
) -> Any:
    """All-reduce-mean gradients over `axes` with an int8 wire format.

    Each leaf: quantize locally -> psum int32 payload + f32 scales -> decode
    with the max scale.  Wire bytes: 1/4 of f32 (plus one scalar per leaf).
    """

    def local(flat_grads, key):
        n = jax.lax.psum(1, axes)
        out = []
        for i, g in enumerate(flat_grads):
            kq = jax.random.fold_in(key, i)
            q, scale = int8_encode(g.astype(jnp.float32), kq)
            # shared scale: max over participants so payloads are commensurate
            scale = jax.lax.pmax(scale, axes)
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            total = jax.lax.psum(q.astype(jnp.int32), axes)
            out.append(total.astype(jnp.float32) * scale / n)
        return tuple(out)

    flat, treedef = jax.tree_util.tree_flatten(grads)
    in_specs = (tuple(P() for _ in flat), P())
    fn = shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=tuple(P() for _ in flat),
        check_vma=False,
    )
    out = fn(tuple(flat), key)
    return jax.tree_util.tree_unflatten(treedef, list(out))
