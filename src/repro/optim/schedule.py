"""LR schedules, including MiniCPM's WSD (warmup-stable-decay).

WSD (arXiv:2404.06395 §4): linear warmup to peak, long stable phase at peak,
short exponential/linear decay tail — designed so checkpoints in the stable
phase can branch to a decay at any time (pairs naturally with this repo's
suspend/resume machinery: a preempted job resumed with fewer remaining steps
re-derives its decay point from the schedule, not from wall clock).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def wsd_schedule(
    total_steps: int,
    *,
    warmup_frac: float = 0.01,
    decay_frac: float = 0.1,
    final_scale: float = 0.1,
) -> Callable:
    warmup = max(1, int(total_steps * warmup_frac))
    decay = max(1, int(total_steps * decay_frac))
    stable_end = total_steps - decay

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(step / warmup, 1.0)
        d = jnp.where(
            step <= stable_end,
            1.0,
            1.0 - (1.0 - final_scale) * (step - stable_end) / decay,
        )
        return w * jnp.clip(d, final_scale, 1.0)

    return fn


def cosine_schedule(total_steps: int, *, warmup_frac: float = 0.01,
                    final_scale: float = 0.1) -> Callable:
    warmup = max(1, int(total_steps * warmup_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(step / warmup, 1.0)
        t = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        c = final_scale + (1 - final_scale) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return w * c

    return fn


def constant_schedule(total_steps: int, **_) -> Callable:
    del total_steps
    return lambda step: jnp.float32(1.0)


SCHEDULES = {
    "wsd": wsd_schedule,
    "cosine": cosine_schedule,
    "constant": constant_schedule,
}


def make_schedule(name: str, total_steps: int, **kw) -> Callable:
    return SCHEDULES[name](total_steps, **kw)
