from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import SCHEDULES, make_schedule, wsd_schedule

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "SCHEDULES",
    "make_schedule",
    "wsd_schedule",
]
