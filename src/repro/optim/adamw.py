"""AdamW with fp32 master weights for bf16 models.

State layout (all pytrees mirroring params):
- master: fp32 master copy (omitted when params are already fp32)
- mu, nu: fp32 first/second moments
- count: scalar step

Sharding: moments and master inherit the *parameter's* PartitionSpec (the
update is elementwise), so optimizer memory scales down with TP exactly like
the parameters do — including the fan-in fallback cases (see
parallel.resolve).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if any(p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    masters = state.get("master", params)

    def upd(p_master, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p_new = p_master - lr * (step + cfg.weight_decay * p_master)
        return p_new, mu, nu

    flat_m, treedef = jax.tree_util.tree_flatten(masters)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_mu = jax.tree_util.tree_flatten(state["mu"])[0]
    flat_nu = jax.tree_util.tree_flatten(state["nu"])[0]
    out = [upd(m, g, mu, nu)
           for m, g, mu, nu in zip(flat_m, flat_g, flat_mu, flat_nu)]
    new_master = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])

    # cast back to the model dtype
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), new_master, params
    )
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics
