from repro.data.synthetic import ClusterSpec, make_blobs
from repro.data.tokens import TokenBatch, synthetic_token_batches

__all__ = ["ClusterSpec", "make_blobs", "TokenBatch", "synthetic_token_batches"]
