"""The paper's synthetic dataset generator.

Paper §II.C: "We generate normally distributed random data with randomly
selected cluster centers and randomly selected variances.  Different
variances are allowed for each feature [...].  All data items are shuffled
randomly before the execution of the data mining algorithms."

Grid used by the paper: features ∈ {1,2,4}, clusters ∈ {2,4,6,8},
points-per-cluster ∈ {128,256,512,1024,2048} → 60 tuples.  The same grid is
exported for the paradigm benchmarks; arbitrary dimensionality / counts /
unequal cluster sizes are supported as in the paper.

All generation is pure (jax PRNG keys in, arrays out) so datasets are
reproducible across hosts — a requirement for restartable jobs: a resumed job
regenerates bit-identical data from the key stored in its checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# The paper's 60-tuple grid.
PAPER_FEATURES = (1, 2, 4)
PAPER_CLUSTERS = (2, 4, 6, 8)
PAPER_CLUSTER_SIZES = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """One tuple of the paper's benchmark grid."""

    features: int
    clusters: int
    points_per_cluster: int

    @property
    def n_points(self) -> int:
        return self.clusters * self.points_per_cluster

    # The paper's fixed hyper-parameter rules (§II.C):
    @property
    def dbscan_min_pts(self) -> int:
        return 10 * self.features

    @property
    def dbscan_eps(self) -> float:
        return float(np.sqrt(self.features))


def paper_grid() -> Tuple[ClusterSpec, ...]:
    return tuple(
        ClusterSpec(f, c, s)
        for f in PAPER_FEATURES
        for c in PAPER_CLUSTERS
        for s in PAPER_CLUSTER_SIZES
    )


def make_blobs(
    key: jax.Array,
    spec: ClusterSpec,
    *,
    center_range: float = 10.0,
    min_sigma: float = 0.15,
    max_sigma: float = 0.8,
    sizes: Sequence[int] | None = None,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Generate shuffled gaussian clusters.

    Returns ``(points, true_labels, centers)`` with
    ``points.shape == (n, features)``.  ``sizes`` overrides equal cluster
    sizes (paper: "allows to generate clusters with unequal cluster sizes").
    Single precision by default, as in the paper.
    """
    k_centers, k_sigma, k_noise, k_shuffle = jax.random.split(key, 4)
    c, f = spec.clusters, spec.features
    if sizes is None:
        sizes = [spec.points_per_cluster] * c
    if len(sizes) != c:
        raise ValueError(f"sizes has {len(sizes)} entries for {c} clusters")
    n = int(sum(sizes))

    centers = jax.random.uniform(
        k_centers, (c, f), minval=-center_range, maxval=center_range, dtype=dtype
    )
    # per-cluster, per-feature variances (paper: different variances per feature)
    sigmas = jax.random.uniform(
        k_sigma, (c, f), minval=min_sigma, maxval=max_sigma, dtype=dtype
    )
    labels = jnp.repeat(
        jnp.arange(c, dtype=jnp.int32), jnp.asarray(sizes), total_repeat_length=n
    )
    noise = jax.random.normal(k_noise, (n, f), dtype=dtype)
    points = centers[labels] + noise * sigmas[labels]

    perm = jax.random.permutation(k_shuffle, n)
    return points[perm], labels[perm], centers
