"""Token pipeline for the LM substrate.

Synthetic-corpus batches are pure functions of (key, step), which makes the
pipeline *restartable by construction*: a resumed job replays the exact batch
stream from the step counter in its checkpoint — the WorkManager property
(jobs survive restarts) applied to data.

For the [vlm]/[audio] backbones the same generator produces precomputed
patch/frame embeddings (the modality frontends are stubs per the assignment;
see models/frontends.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TokenBatch:
    """One training batch.

    tokens/labels: (batch, seq) int32; labels are tokens shifted left.
    embeddings: optional (batch, frames, d_model) float for stub frontends.
    """

    tokens: jax.Array
    labels: jax.Array
    embeddings: Optional[jax.Array] = None


def synthetic_token_batch(
    key: jax.Array,
    *,
    batch: int,
    seq: int,
    vocab: int,
    skew: float = 4.0,
) -> TokenBatch:
    """Power-law token ids: p(id) ∝ id^(1/skew - 1), O(B*S) sampling.

    (Uniform ids make loss curves degenerate; a true Zipf categorical costs
    O(B*S*V) — this inverse-CDF power law gives the heavy head at gather
    cost.)
    """
    u = jax.random.uniform(key, (batch, seq), minval=1e-9, maxval=1.0)
    ids = jnp.clip((vocab * u ** skew).astype(jnp.int32), 0, vocab - 1)
    labels = jnp.concatenate([ids[:, 1:], ids[:, :1]], axis=1)
    return TokenBatch(tokens=ids, labels=labels)


def synthetic_token_batches(
    key: jax.Array,
    *,
    batch: int,
    seq: int,
    vocab: int,
    start_step: int = 0,
) -> Iterator[TokenBatch]:
    """Infinite, replayable batch stream keyed by step index."""
    step = start_step
    while True:
        yield synthetic_token_batch(
            jax.random.fold_in(key, step), batch=batch, seq=seq, vocab=vocab
        )
        step += 1
