"""Resolve parameter/cache shardings for a concrete (config, mesh) pair.

Built on the declaration trees (models.declare): every leaf carries logical
axes; this module turns them into PartitionSpecs with two refinements over
the raw table lookup:

1. **Shape-aware degradation** (spec_for_shape): published dims that don't
   divide the mesh axis (36 heads, kv=2, 24 heads on 16-way TP) are
   replicated instead of failing.

2. **Fan-in fallback**: if an attention projection lost its "heads" sharding
   to rule 1, the freed "model" axis is re-assigned to the tensor's "embed"
   (fan-in/fan-out) dim when that divides.  This keeps the parameter + its
   optimizer state sharded 16-way (a ZeRO-for-TP property) at the cost of a
   replicated attention core — measured and attacked in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import (
    ShardingRules,
    _axes_size,
    _filter_axes,
    spec_for_shape,
)

_FALLBACK_TRIGGERS = ("heads", "kv_heads", "vocab", "ff", "expert",
                      "ssm_inner")
_FALLBACK_TARGET = "embed"


def spec_for_decl(
    rules: ShardingRules,
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
) -> P:
    spec = spec_for_shape(rules, axes, mesh, shape)
    entries = list(spec) + [None] * (len(shape) - len(spec))

    # did a trigger dim lose its model sharding?
    model_axes = _filter_axes(mesh, "model")
    if model_axes is None:
        return spec
    lost = False
    model_used = False
    for ax, ent in zip(axes, entries):
        wanted = rules.get(ax)
        wants_model = wanted == "model" or (
            isinstance(wanted, tuple) and "model" in wanted
        )
        has_model = ent == "model" or (
            isinstance(ent, tuple) and "model" in ent
        )
        if has_model:
            model_used = True
        if ax in _FALLBACK_TRIGGERS and wants_model and not has_model:
            lost = True
    if not lost or model_used:
        return spec

    # re-assign 'model' to the embed (fan) dim if it divides
    for i, (ax, ent, dim) in enumerate(zip(axes, entries, shape)):
        if ax == _FALLBACK_TARGET and ent is None and \
                dim % _axes_size(mesh, "model") == 0:
            entries[i] = "model"
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: add the data axes to the first shardable replicated dim.

    Optimizer state (fp32 master + moments) is elementwise in the update,
    so it can shard over (pod, data) on top of TP — GSPMD turns the grad
    flow into reduce-scatter(grads) -> sharded update -> all-gather(params),
    the standard ZeRO-1 schedule.  Cuts per-chip optimizer bytes by the DP
    degree (16-32x); measured in EXPERIMENTS.md §Perf iteration Z.
    """
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not daxes:
        return spec
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    if any(a in ("pod", "data") or
           (isinstance(a, tuple) and any(x in ("pod", "data") for x in a))
           for a in entries if a):
        return spec
    for i, (ent, dim) in enumerate(zip(entries, shape)):
        if ent is None and dim % dsize == 0:
            entries[i] = daxes if len(daxes) > 1 else daxes[0]
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_specs(axes_tree: Any, abstract_tree: Any, mesh: Mesh,
               rules: ShardingRules) -> Any:
    """Map (axes tree, ShapeDtypeStruct tree) -> PartitionSpec tree."""
    return jax.tree_util.tree_map(
        lambda ax, ab: spec_for_decl(rules, tuple(ax), tuple(ab.shape), mesh),
        axes_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


def tree_shardings(axes_tree: Any, abstract_tree: Any, mesh: Mesh,
                   rules: ShardingRules) -> Any:
    specs = tree_specs(axes_tree, abstract_tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_state_shardings(state_axes: Any, state_abs: Any, mesh: Mesh,
                          rules: ShardingRules, zero1: bool = True,
                          zero3: bool = False) -> Any:
    """Shardings for a TrainState: params per rules; optimizer state with
    ZeRO-1 (data-axes) sharding layered on top; zero3 additionally shards
    the parameters themselves over the data axes (per-layer all-gather)."""
    import dataclasses as _dc  # noqa: PLC0415

    base = tree_shardings(state_axes, state_abs, mesh, rules)
    if not zero1 and not zero3:
        return base

    def z1(sh, ab):
        spec = zero1_spec(sh.spec, tuple(ab.shape), mesh)
        return NamedSharding(mesh, spec)

    opt = dict(base.opt)
    if zero1 or zero3:
        for key in ("mu", "nu", "master"):
            if key in opt:
                opt[key] = jax.tree_util.tree_map(
                    z1, opt[key], state_abs.opt[key]
                )
    params = base.params
    if zero3:
        params = jax.tree_util.tree_map(z1, base.params, state_abs.params)
    return _dc.replace(base, opt=opt, params=params)
