from repro.parallel.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_spec,
    lshard,
    logical_axis_rules,
)

__all__ = [
    "DEFAULT_RULES",
    "ShardingRules",
    "logical_to_spec",
    "lshard",
    "logical_axis_rules",
]
