"""Pipeline parallelism: GPipe schedule over shard_map + collective_permute.

Optional parallelism axis for depth-dominated models (the mandated
production mesh is (pod, data, model); a PP deployment reshapes to
(pod, data, model, pipe) — the sharding-rules table makes that a config
change, not a code change).

Design: the layer stack is split into `P` contiguous stages.  Under
shard_map over the 'pipe' axis every device holds its stage's parameters;
microbatches stream through the ring with `lax.ppermute`.  The schedule is
the classic GPipe fill-drain loop of length M + P - 1; each device computes
every tick (idle ticks compute on garbage and are masked — on TPU the
predictable dataflow beats divergent control flow).

The loop is `lax.fori_loop`-free on purpose: a Python loop of M + P - 1
ticks unrolls into a static HLO pipeline XLA can overlap (ppermute of tick
t+1 against compute of tick t — the latency-hiding scheduler sees
independent ops).  Autodiff works through ppermute (its transpose is the
reverse permute), so `jax.grad` of a pipelined loss is pipeline-parallel
backward for free.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from repro.runtime.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    mesh: Mesh,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    axis: str = "pipe",
):
    """Build a pipelined apply: (stage_params, x_microbatches) -> y.

    stage_params: pytree whose leaves have a leading 'pipe'-sharded stage
    dim (one slice per device).  x_microbatches: (M, mb, ...) replicated.
    Returns (M, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]

    def local(params, xs):
        # params: stage slice (leading dim 1); xs: (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        m = xs.shape[0]
        ticks = m + n_stages - 1
        buf = jnp.zeros_like(xs[0])          # inter-stage buffer
        outs = jnp.zeros_like(xs)

        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        for t in range(ticks):
            mb = t - stage                   # microbatch index at my stage
            active = (mb >= 0) & (mb < m)
            # stage 0 reads from the input stream, others from the ring
            x_in = jnp.where(
                stage == 0,
                xs[jnp.clip(t, 0, m - 1)],
                buf,
            )
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage emits; use dynamic index, masked
            emit = active & (stage == n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(emit, y, outs[jnp.clip(mb, 0, m - 1)]),
                jnp.clip(mb, 0, m - 1),
                axis=0,
            )
            buf = jax.lax.ppermute(y, axis, fwd)
        # replicate results (only the last stage holds them)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis,
        )
        return outs

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )


def split_stages(tree: Any, n_stages: int) -> Any:
    """Reshape stacked layer params (L, ...) -> (n_stages, L/n_stages, ...)."""

    def f(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])

    return jax.tree.map(f, tree)
