"""Logical-axis sharding rules: one table drives DP/TP/EP/SP.

Every parameter and activation in the model layer is annotated with *logical*
axis names ("batch", "heads", "ff", "expert", ...).  This module maps logical
axes to physical mesh axes, so the same model code runs on the single-pod
(16, 16) ``(data, model)`` mesh, the multi-pod (2, 16, 16)
``(pod, data, model)`` mesh, a tiny test mesh, or one device — only the rules
change.  This is also what makes elastic restart trivial: checkpoints store
logical arrays; shardings are re-derived from the rules on the new mesh
(checkpoint/elastic.py).

Parallelism styles expressed purely through the table:
- DP: "batch" -> ("pod", "data")
- TP: "heads"/"ff"/"vocab"/"ssm_inner" -> "model"
- EP: "expert" -> "model"
- SP: "seq_shard" -> "data" (long-context decode: KV/state sharded over seq)
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axes (or None = replicated)."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    def get(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def override(self, **kw: MeshAxes) -> "ShardingRules":
        table = tuple((k, kw.pop(k, v)) for k, v in self.table)
        table += tuple(kw.items())
        return ShardingRules(table)


DEFAULT_RULES = ShardingRules(
    table=(
        # activations
        ("batch", ("pod", "data")),
        ("seq", None),              # sequence replicated by default
        ("seq_kv", None),           # KV-cache seq dim (SP override -> "data")
        ("seq_shard", "data"),      # SP: long-context KV/state sharding
        ("embed", None),            # residual stream replicated
        ("heads", "model"),
        ("kv_heads", "model"),
        ("head_dim", None),
        ("ff", "model"),
        ("vocab", "model"),
        ("expert", "model"),
        ("expert_capacity", None),
        ("ssm_inner", "model"),
        ("ssm_state", None),
        ("conv_kernel", None),
        ("dt_rank", None),
        ("layers", None),           # stacked scan groups
        # clustering (the paper's side of the house)
        ("points", ("pod", "data")),
        ("centroids", "model"),
        ("features", None),
    )
)


def _filter_axes(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 1 pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    present = tuple(a for a in axes if a in mesh.axis_names)
    return present if present else None


def logical_to_spec(
    rules: ShardingRules, logical_axes: Tuple[Optional[str], ...],
    mesh: Optional[Mesh] = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    spec = []
    for ax in logical_axes:
        m = rules.get(ax)
        if mesh is not None:
            m = _filter_axes(mesh, m)
        spec.append(m)
    # drop trailing Nones (canonical form)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def named_sharding(
    mesh: Mesh, rules: ShardingRules, logical_axes: Tuple[Optional[str], ...]
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(rules, logical_axes, mesh))


# -- in-model constraints ----------------------------------------------------------

_ACTIVE_RULES: list = [DEFAULT_RULES]


@contextlib.contextmanager
def logical_axis_rules(rules: ShardingRules):
    _ACTIVE_RULES.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE_RULES.pop()


def current_rules() -> ShardingRules:
    return _ACTIVE_RULES[-1]


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources  # noqa: PLC0415

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def _axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for_shape(
    rules: ShardingRules,
    logical_axes: Tuple[Optional[str], ...],
    mesh: Mesh,
    shape: Tuple[int, ...],
) -> P:
    """Shape-aware spec: drops mesh axes that do not divide the dim evenly.

    GSPMD requires even divisibility at jit boundaries; published configs
    include odd sizes (36 heads, vocab 92553 pre-padding, kv=2), so sharding
    degrades per-tensor instead of failing: a non-divisible dim is
    replicated (and parallel.resolve may re-assign the freed mesh axis to a
    fan-in dim — see resolve_param_specs).
    """
    spec = []
    used: set = set()
    for ax, dim in zip(logical_axes, shape):
        m = _filter_axes(mesh, rules.get(ax))
        if isinstance(m, str):
            m = (m,)
        if m is not None:
            m = tuple(a for a in m if a not in used)
            # greedy prefix that divides the dim
            while m and dim % _axes_size(mesh, m) != 0:
                m = m[:-1]
            m = m or None
        if m is not None:
            used.update(m)
            spec.append(m if len(m) > 1 else m[0])
        else:
            spec.append(None)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def lshard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain an activation to its logical sharding (no-op without mesh).

    The no-op path keeps all model code runnable on one CPU device (smoke
    tests) while the dry-run gets full GSPMD constraints.  Shape-aware: axes
    that don't divide are left unconstrained rather than failing.
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    rules = current_rules()
    spec = spec_for_shape(rules, tuple(logical_axes), mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
