from repro.kernels.neighbor.ops import epsilon_degree, expand_frontier

__all__ = ["epsilon_degree", "expand_frontier"]
