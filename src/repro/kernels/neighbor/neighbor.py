"""Pallas TPU kernels: DBSCAN epsilon-neighborhood queries.

The paper uses two OpenCL kernels with "almost the same purpose": one decides
core-point reachability in the main loop, one expands clusters.  Both reduce
to rows of the epsilon-adjacency matrix A = [ d2(i,j) <= eps^2 ].  On the Mali
GPU each work-item scans its row; on TPU we tile the n x n matrix into
(bn, bm) VMEM blocks, build each tile from the MXU decomposition

    d2 = ||x_i||^2 - 2 x_i . x_j + ||x_j||^2

and reduce tiles on the fly so A is **never materialized in HBM** (the
quadratic object exists only one VMEM tile at a time — the TPU analogue of
the paper's pinned zero-copy buffers).

Kernel 1 — degree:   deg[i]     = sum_j A[i, j]            (VPU row reduce)
Kernel 2 — expand:   reach[i]   = sum_j A[i, j] * front[j]  (MXU mat-vec)

Layout: grid (row-tiles, col-tiles), col dimension sequential ("arbitrary")
because it carries the running accumulator in the output VMEM block.
eps^2 arrives as a (1, 1) SMEM-style operand rather than a captured constant
so eps sweeps do not retrace.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

DEFAULT_BLOCK_I = 512
DEFAULT_BLOCK_J = 512


def _tile_d2(xi, xj):
    """Squared-distance tile via the MXU decomposition, fp32."""
    xi = xi.astype(jnp.float32)
    xj = xj.astype(jnp.float32)
    cross = jax.lax.dot_general(
        xi, xj,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    ni = jnp.sum(xi * xi, axis=1)  # (bi,)
    nj = jnp.sum(xj * xj, axis=1)  # (bj,)
    return ni[:, None] - 2.0 * cross + nj[None, :]


def _degree_kernel(eps2_ref, xi_ref, xj_ref, deg_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        deg_ref[...] = jnp.zeros_like(deg_ref)

    d2 = _tile_d2(xi_ref[...], xj_ref[...])
    adj = (d2 <= eps2_ref[0, 0]).astype(jnp.int32)
    deg_ref[...] += jnp.sum(adj, axis=1, keepdims=True)


def _expand_kernel(eps2_ref, xi_ref, xj_ref, front_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d2 = _tile_d2(xi_ref[...], xj_ref[...])
    adj = (d2 <= eps2_ref[0, 0]).astype(jnp.float32)
    # (bi, bj) @ (bj, 1) on the MXU: count of frontier neighbors in this tile
    out_ref[...] += jax.lax.dot_general(
        adj, front_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def degree_kernel(
    x: jnp.ndarray,
    eps2: jnp.ndarray,
    *,
    block_i: int = DEFAULT_BLOCK_I,
    block_j: int = DEFAULT_BLOCK_J,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pre-padded entry: x (n, d), n % block == 0, d % 128 == 0 -> (n, 1) i32."""
    n, d = x.shape
    assert n % block_i == 0 and n % block_j == 0 and d % 128 == 0
    grid = (n // block_i, n // block_j)
    return pl.pallas_call(
        _degree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
        **tpu_compiler_params(("parallel", "arbitrary"), interpret=interpret),
    )(eps2.reshape(1, 1), x, x)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def expand_kernel(
    x: jnp.ndarray,
    frontier: jnp.ndarray,
    eps2: jnp.ndarray,
    *,
    block_i: int = DEFAULT_BLOCK_I,
    block_j: int = DEFAULT_BLOCK_J,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pre-padded entry: frontier (n, 1) f32 in {0,1} -> neighbor counts (n, 1) f32."""
    n, d = x.shape
    assert frontier.shape == (n, 1)
    assert n % block_i == 0 and n % block_j == 0 and d % 128 == 0
    grid = (n // block_i, n // block_j)
    return pl.pallas_call(
        _expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_j, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
        **tpu_compiler_params(("parallel", "arbitrary"), interpret=interpret),
    )(eps2.reshape(1, 1), x, x, frontier)
