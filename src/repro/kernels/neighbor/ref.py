"""Pure-jnp oracles for the DBSCAN neighborhood kernels."""

from __future__ import annotations

import jax.numpy as jnp


def _sq_dists(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def epsilon_degree_ref(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Number of points within eps (inclusive, self counted) per point."""
    d2 = _sq_dists(x)
    return jnp.sum(d2 <= jnp.float32(eps) ** 2, axis=1).astype(jnp.int32)


def expand_frontier_ref(
    x: jnp.ndarray, frontier: jnp.ndarray, eps: float
) -> jnp.ndarray:
    """Points within eps of any frontier point (bool (n,)).

    The paper's cluster-expansion kernel: "examine if a data point is
    (directly) reachable from a given core point", batched over the whole
    frontier at once.
    """
    d2 = _sq_dists(x)
    adj = d2 <= jnp.float32(eps) ** 2
    return jnp.any(adj & frontier[None, :], axis=1)
