"""Jit'd public wrappers for the DBSCAN neighborhood kernels.

Padding contract: points are padded with a large coordinate (1e10) in the
first feature column, which puts padding at squared distance >= ~1e20 from
every real point — outside any realistic eps — without overflowing fp32 in
the norm decomposition.  Padding frontier entries are zero so they can never
spread reachability.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.neighbor.neighbor import (
    DEFAULT_BLOCK_I,
    DEFAULT_BLOCK_J,
    degree_kernel,
    expand_kernel,
)

_PAD_COORD = 1e10


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_points(x: jnp.ndarray, block: int):
    n, d = x.shape
    n_pad = _round_up(n, block)
    d_pad = _round_up(d, 128)
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:, 0].set(_PAD_COORD)
    xp = xp.at[:n, :d].set(x)
    xp = xp.at[:n, d:].set(0.0)
    return xp, n_pad, d_pad


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def epsilon_degree(
    x: jnp.ndarray,
    eps: jnp.ndarray | float,
    *,
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """|N_eps(p)| for every point (self included), int32 (n,)."""
    if interpret is None:
        interpret = _default_interpret()
    n, _ = x.shape
    bi = block_i or min(DEFAULT_BLOCK_I, _round_up(n, 8))
    bj = block_j or min(DEFAULT_BLOCK_J, _round_up(n, 8))
    b = max(bi, bj)
    xp, _, _ = _pad_points(x, b)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    deg = degree_kernel(xp, eps2, block_i=bi, block_j=bj, interpret=interpret)
    return deg[:n, 0]


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def expand_frontier(
    x: jnp.ndarray,
    frontier: jnp.ndarray,
    eps: jnp.ndarray | float,
    *,
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Bool (n,): within eps of some frontier point (the expansion kernel)."""
    if interpret is None:
        interpret = _default_interpret()
    n, _ = x.shape
    bi = block_i or min(DEFAULT_BLOCK_I, _round_up(n, 8))
    bj = block_j or min(DEFAULT_BLOCK_J, _round_up(n, 8))
    b = max(bi, bj)
    xp, n_pad, _ = _pad_points(x, b)
    fp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(
        frontier.astype(jnp.float32)
    )
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    counts = expand_kernel(xp, fp, eps2, block_i=bi, block_j=bj,
                           interpret=interpret)
    return counts[:n, 0] > 0.5
