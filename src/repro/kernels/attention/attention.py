"""Pallas TPU kernel: causal flash attention (forward), online softmax.

The serving-path hot spot (32k prefill).  Grid: (batch*heads, q-blocks,
k-blocks), k-dimension sequential ("arbitrary") because it carries the
online-softmax running state in VMEM scratch:

    m (bq, 1)  running row max        — VPU reduce per tile
    l (bq, 1)  running normalizer
    acc (bq, d) unnormalized output   — accumulated in fp32 in VMEM

MXU feeds: the (bq, d) x (d, bk) score tile and the (bq, bk) x (bk, d)
value tile.  Block sizes default (256, 512) so the working set
(q + k + v + scores + acc ~ (bq+2bk)*d*4 + bq*bk*4) stays well inside the
16 MB/core VMEM at d=128.

Causal handling: whole k-blocks strictly above the diagonal are skipped
(pl.when on block indices — Mosaic elides the compute); the diagonal block
applies an element mask.  Padded key positions (seq not divisible by the
block) are masked via the kv_len scalar operand.

Forward-only by design: training attention goes through XLA (DESIGN.md §4)
— the dry-run cost model must see the attention FLOPs, and a custom-call
would hide them; serving uses this kernel on real hardware.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -1e30, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: skip k-blocks entirely above the diagonal
    live = (k_start <= q_start + block_q - 1) if causal else (k_start >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                 # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < len_ref[0, 0]                       # padded keys
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, -1e30)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "interpret"),
)
def flash_attention_kernel(
    q: jnp.ndarray,      # (BH, Sq, D) pre-padded
    k: jnp.ndarray,      # (BH, Sk, D)
    v: jnp.ndarray,
    kv_len: jnp.ndarray,  # () int32: true (unpadded) key length
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    causal: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    scale = 1.0 / math.sqrt(d)
    grid = (bh, sq // block_q, sk // block_k)

    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        scratch = [
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ]
    except ImportError:  # pure-interpret fallback
        scratch = [
            pl.MemoryRef((block_q, 1), jnp.float32),  # pragma: no cover
            pl.MemoryRef((block_q, 1), jnp.float32),
            pl.MemoryRef((block_q, d), jnp.float32),
        ]

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k,
            scale=scale, causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **tpu_compiler_params(("parallel", "parallel", "arbitrary"),
                              interpret=interpret),
    )(kv_len.reshape(1, 1), q, k, v)
