"""Pure-jnp oracle for the flash attention kernel (causal, GQA)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,   # (B, Sq, H, D)
    k: jnp.ndarray,   # (B, Sk, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    causal_offset: int = 0,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    kf = jnp.repeat(k, group, axis=2) if group > 1 else k
    vf = jnp.repeat(v, group, axis=2) if group > 1 else v
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qpos = jnp.arange(sq) + causal_offset
        kpos = jnp.arange(kf.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vf.astype(jnp.float32)).astype(
        q.dtype
    )
