"""Public wrapper for flash attention: layout + GQA + padding handling."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.attention.attention import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_kernel,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, Sq, H, D)
    k: jnp.ndarray,   # (B, Sk, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Returns (B, Sq, H, D).  H % KV == 0 (GQA: kv repeated)."""
    if interpret is None:
        interpret = _default_interpret()
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    bq = block_q or min(DEFAULT_BLOCK_Q, _round_up(sq, 8))
    bk = block_k or min(DEFAULT_BLOCK_K, _round_up(sk, 8))
    sq_pad = _round_up(sq, bq)
    sk_pad = _round_up(sk, bk)

    def to_bh(x, s_pad):
        x = jnp.pad(x, ((0, 0), (0, s_pad - x.shape[1]), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(b * h, s_pad, d)

    qf = to_bh(q, sq_pad)
    kf = to_bh(k, sk_pad)
    vf = to_bh(v, sk_pad)
    out = flash_attention_kernel(
        qf, kf, vf, jnp.int32(sk),
        block_q=bq, block_k=bk, causal=causal, interpret=interpret,
    )
    out = out.reshape(b, h, sq_pad, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
