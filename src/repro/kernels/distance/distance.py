"""Pallas TPU kernel: K-Means assignment (distance-to-centroids + argmin).

TPU adaptation of the paper's OpenCL assignment kernel.  On the Mali GPU each
work-item loops over centroids computing one distance at a time.  On TPU the
same computation is recast for the MXU:

    ||x - c||^2 = ||x||^2 - 2 x·c + ||c||^2

The cross term is a (bn, d) x (d, bk) matmul executed on the 128x128 systolic
array; ||c||^2 is a cheap VPU reduction per centroid tile; ||x||^2 is constant
per point so it cannot change the argmin and is *omitted inside the kernel*
(ops.py adds it back when true distances are requested).  This turns a
bandwidth-bound per-point loop into a compute-dense tile loop — the TPU
version of the paper's "avoid unnecessary memory operations" advice
(CL_MEM_USE_HOST_PTR / pinned buffers): the running (min, argmin) pair for a
point-tile lives in the output VMEM block across all centroid tiles and is
written to HBM exactly once.

Layout notes:
- block shapes are multiples of (8, 128) (VPU lanes) and feed the MXU with
  d padded to a multiple of 128;
- the grid is (points-tiles, centroid-tiles) with the centroid dimension
  marked "arbitrary" (sequential) because it carries the running min;
- outputs are (n, 1)-shaped so Mosaic keeps them as [8,128]-tileable 2D refs;
  ops.py squeezes them.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

DEFAULT_BLOCK_N = 512   # points per tile
DEFAULT_BLOCK_K = 128   # centroids per tile

_BIG = 3.4e38  # +inf stand-in that survives arithmetic (python float: kernels
# must not capture traced constants)


def _assign_kernel(x_ref, c_ref, val_ref, idx_ref, *, block_k: int):
    """One (point-tile, centroid-tile) grid step.

    x_ref:   (bn, d)  VMEM — point tile
    c_ref:   (bk, d)  VMEM — centroid tile
    val_ref: (bn, 1)  VMEM — running min of (||c||^2 - 2 x·c)  (persistent)
    idx_ref: (bn, 1)  VMEM — running argmin (persistent)
    """
    j = pl.program_id(1)

    # init the running pair on the first centroid tile
    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, _BIG, val_ref.dtype)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    # MXU: cross term.  (bn, d) @ (d, bk) -> (bn, bk), fp32 accumulation.
    cross = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    cnorm = jnp.sum(c * c, axis=1)  # (bk,)
    # score = ||c||^2 - 2 x·c  (+||x||^2 omitted: constant per row)
    score = cnorm[None, :] - 2.0 * cross  # (bn, bk)

    # tile-local (min, first-argmin)
    tile_min = jnp.min(score, axis=1, keepdims=True)  # (bn, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    tile_idx = jnp.min(
        jnp.where(score == tile_min, col, jnp.int32(block_k)), axis=1, keepdims=True
    ) + j * block_k  # global centroid index, first occurrence within tile

    # combine with the running pair; strict < keeps the first (lowest-j) winner
    run_val = val_ref[...]
    better = tile_min < run_val
    val_ref[...] = jnp.where(better, tile_min, run_val)
    idx_ref[...] = jnp.where(better, tile_idx, idx_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret")
)
def assign_clusters_kernel(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Raw kernel entry.  Requires pre-padded shapes:

    x: (n, d) with n % block_n == 0, d % 128 == 0
    c: (k, d) with k % block_k == 0; padding centroid rows must be _BIG-normed
       (ops.py pads with 1e19 so they never win the argmin).

    Returns (score_min (n,1) f32, argmin (n,1) i32) where score omits ||x||^2.
    """
    n, d = x.shape
    k, dc = c.shape
    assert d == dc, (d, dc)
    assert n % block_n == 0 and k % block_k == 0 and d % 128 == 0

    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_assign_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
        **tpu_compiler_params(("parallel", "arbitrary"), interpret=interpret),
    )(x, c)
