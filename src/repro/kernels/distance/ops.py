"""Jit'd public wrappers around the K-Means assignment kernel.

Handles shape padding to the kernel's tiling contract:
- points padded to a multiple of ``block_n`` with zero rows (sliced off);
- feature dim padded to a multiple of 128 with zeros (distance-neutral);
- centroids padded to a multiple of ``block_k`` with rows of 1e19 so padding
  can never win the argmin (the kernel treats centroid norms as scores).

``interpret`` defaults to True on non-TPU backends so the same call sites run
on this CPU container and compile to Mosaic on real v5e.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.distance.distance import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_N,
    assign_clusters_kernel,
)
from repro.kernels.distance.ref import pairwise_sq_dists_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret", "with_dists")
)
def assign_clusters(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    with_dists: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-centroid assignment.

    Args:
      x: (n, d) points.
      c: (k, d) centroids.
    Returns:
      (assignment int32 (n,), min squared distance f32 (n,)).
      If ``with_dists=False`` the second output is the kernel score
      (distance minus ||x||^2) — cheaper, argmin-equivalent.
    """
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    k, _ = c.shape

    bn = block_n or min(DEFAULT_BLOCK_N, _round_up(n, 8))
    bk = block_k or min(DEFAULT_BLOCK_K, _round_up(k, 8))

    n_pad = _round_up(n, bn)
    k_pad = _round_up(k, bk)
    d_pad = _round_up(d, 128)

    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    # padding centroids: huge coordinates -> huge ||c||^2 score, never chosen
    cp = jnp.full((k_pad, d_pad), 0.0, c.dtype).at[:, :1].set(1e19)
    cp = cp.at[:k, :d].set(c)

    score, idx = assign_clusters_kernel(
        xp, cp, block_n=bn, block_k=bk, interpret=interpret
    )
    idx = idx[:n, 0]
    score = score[:n, 0]
    if with_dists:
        xnorm = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
        # clamp tiny negatives from the decomposition (catastrophic
        # cancellation when a point sits on a centroid)
        score = jnp.maximum(score + xnorm, 0.0)
    return idx, score


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Full (n, k) squared-distance matrix (oracle-backed; small inputs)."""
    return pairwise_sq_dists_ref(x, c)
