"""Fused masked K-Means step kernel: one pass over the points matrix.

The unfused Lloyd step (``core/kmeans.py``) reads ``x`` twice — once in the
assignment kernel, once in the one-hot centroid-update matmul — and pushes
the full ``(n, k)`` one-hot intermediate (plus the assignment vector)
through HBM between the two.  This kernel computes distances, the argmin
assignment, *and* the masked per-centroid sum/count/inertia accumulators in
a single pass over each point tile, so per step ``x`` streams through VMEM
exactly once and the only HBM outputs are the assignment ``(n, 1)`` and the
``(k, d)``-sized accumulators.  ``benchmarks/roofline.py`` quantifies the
traffic saved (the Green-Computing survey's "memory operations dominate"
finding, applied to our own hot loop).

Kernel layout (all distance.py conventions kept):
- grid is point tiles only, marked "arbitrary" (sequential): the sum /
  count / inertia output blocks map every grid step to block (0, 0), so
  they live in VMEM across the whole pass and are written to HBM once;
- the full padded centroid matrix rides in VMEM per tile (k is small for
  clustering workloads — k_pad * d_pad floats);
- the cross term and the one-hotᵀ·x update are both MXU matmuls;
- padding centroid rows carry 1e19 in feature 0 (ops.py scheme), so they
  can never win the argmin and therefore never accumulate mass;
- masked-out point rows enter with weight 0: they are still *assigned*
  (row-wise work, sliced off by the wrapper) but contribute nothing to the
  sums, counts, or inertia — identical semantics to ``masked_kmeans_step``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params
from repro.kernels.distance.distance import DEFAULT_BLOCK_N, _BIG
from repro.kernels.distance.ops import _default_interpret, _round_up


def _fused_step_kernel(x_ref, c_ref, w_ref, idx_ref, sums_ref, cnt_ref,
                       inert_ref, *, block_k: int):
    """One point-tile grid step.

    x_ref:     (bn, d)  VMEM — point tile
    c_ref:     (kp, d)  VMEM — the WHOLE padded centroid matrix
    w_ref:     (bn, 1)  VMEM — per-point mask weight (0.0 for padding)
    idx_ref:   (bn, 1)  VMEM — assignment for this tile
    sums_ref:  (kp, d)  VMEM — masked per-centroid coordinate sums (persistent)
    cnt_ref:   (1, kp)  VMEM — masked per-centroid counts (persistent)
    inert_ref: (1, 1)   VMEM — masked inertia accumulator (persistent)
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        inert_ref[...] = jnp.zeros_like(inert_ref)

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)            # (bn, 1)

    # MXU: cross term.  (bn, d) @ (d, kp) -> (bn, kp), fp32 accumulation.
    cross = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    cnorm = jnp.sum(c * c, axis=1)                # (kp,)
    # score = ||c||^2 - 2 x·c; ||x||^2 is argmin-neutral and re-added for
    # the inertia below (we have the tile in hand — no extra pass)
    score = cnorm[None, :] - 2.0 * cross          # (bn, kp)
    score = jnp.minimum(score, _BIG)

    tile_min = jnp.min(score, axis=1, keepdims=True)            # (bn, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    idx = jnp.min(
        jnp.where(score == tile_min, col, jnp.int32(block_k)),
        axis=1, keepdims=True)                    # first-occurrence argmin
    idx_ref[...] = idx

    # in-register masked one-hot: no (n, k) HBM intermediate, and the
    # centroid update becomes a second MXU matmul over the SAME x tile
    onehot = (col == idx).astype(jnp.float32) * w               # (bn, kp)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (kp, d)
    cnt_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).reshape(
        cnt_ref.shape)
    xnorm = jnp.sum(x * x, axis=1, keepdims=True)               # (bn, 1)
    d2 = jnp.maximum(tile_min + xnorm, 0.0)
    inert_ref[...] += jnp.sum(d2 * w, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_step_kernel(
    x: jnp.ndarray,
    c: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Raw kernel entry.  Requires pre-padded shapes:

    x: (n, d) with n % block_n == 0, d % 128 == 0
    c: (k, d) with k % 8 == 0; padding centroid rows must be _BIG-normed
    w: (n, 1) f32 mask weights, 0.0 on every padding row

    Returns (argmin (n,1) i32, sums (k,d) f32, counts (1,k) f32,
    inertia (1,1) f32).
    """
    n, d = x.shape
    k, dc = c.shape
    assert d == dc, (d, dc)
    assert n % block_n == 0 and k % 8 == 0 and d % 128 == 0
    assert w.shape == (n, 1), w.shape

    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_fused_step_kernel, block_k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
        **tpu_compiler_params(("arbitrary",), interpret=interpret),
    )(x, c, w)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_masked_assign_update(
    x: jnp.ndarray,
    c: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block_n: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused assignment + masked accumulation over unpadded shapes.

    Args:
      x: (n, d) points.
      c: (k, d) centroids.
      mask: (n,) bool — False rows carry no weight.
    Returns:
      (assignment i32 (n,), masked sums f32 (k, d), masked counts f32 (k,),
      masked inertia f32 ()).
    """
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    k, _ = c.shape

    bn = block_n or min(DEFAULT_BLOCK_N, _round_up(n, 8))
    n_pad = _round_up(n, bn)
    k_pad = _round_up(k, 8)
    d_pad = _round_up(d, 128)

    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    # padding centroids: huge coordinates -> huge ||c||^2 score, never chosen
    cp = jnp.full((k_pad, d_pad), 0.0, c.dtype).at[:, :1].set(1e19)
    cp = cp.at[:k, :d].set(c)
    wp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(
        mask.astype(jnp.float32))

    idx, sums, cnt, inert = fused_step_kernel(
        xp, cp, wp, block_n=bn, interpret=interpret)
    return idx[:n, 0], sums[:k, :d], cnt[0, :k], inert[0, 0]
