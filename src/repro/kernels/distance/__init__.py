from repro.kernels.distance.ops import assign_clusters, pairwise_sq_dists

__all__ = ["assign_clusters", "pairwise_sq_dists"]
