"""Pure-jnp oracle for the K-Means assignment kernel.

This is the paper's OpenCL K-Means kernel, verbatim in semantics: "one kernel
that calculates in parallel the distance of a point to each cluster center
and saves the cluster number with the lowest distance".
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def pairwise_sq_dists_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distances, (n, d) x (k, d) -> (n, k), fp32 accum."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    # Stable direct form for the oracle (the kernel uses the MXU
    # decomposition; the oracle intentionally uses the naive form so the two
    # are independent implementations).
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def assign_clusters_ref(
    x: jnp.ndarray, c: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (assignment int32 (n,), min squared distance f32 (n,))."""
    d = pairwise_sq_dists_ref(x, c)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)
