"""Version-tolerant helpers for Pallas TPU compiler parameters.

``pallas_call(compiler_params=...)`` changed shape across jax releases
(dict -> pltpu.TPUCompilerParams -> pltpu.CompilerParams).  Kernels in this
repo call :func:`tpu_compiler_params` so the TPU hints (dimension semantics
for the Mosaic scheduler) survive version bumps, and are simply dropped in
interpret mode where they are meaningless.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence


def tpu_compiler_params(
    dimension_semantics: Sequence[str], *, interpret: bool
) -> Dict[str, Any]:
    """kwargs for pallas_call carrying Mosaic dimension semantics."""
    if interpret:
        return {}
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        if hasattr(pltpu, "CompilerParams"):
            return {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=tuple(dimension_semantics)
                )
            }
        if hasattr(pltpu, "TPUCompilerParams"):
            return {
                "compiler_params": pltpu.TPUCompilerParams(
                    dimension_semantics=tuple(dimension_semantics)
                )
            }
    except ImportError:
        pass
    return {
        "compiler_params": {
            "mosaic": {"dimension_semantics": tuple(dimension_semantics)}
        }
    }
